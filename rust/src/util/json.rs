//! Minimal JSON value model, parser and writer.
//!
//! Replaces `serde_json` (unavailable offline). Supports the full JSON
//! grammar with the usual relaxations needed here: `NaN`/`Infinity` are
//! rejected on parse and serialized as `null` (matching serde_json).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `get_path("a.b.c")`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 2f64.powi(63) => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: peek for a following low surrogate.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos + 1..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 3..self.pos + 7],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                cp
                            };
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "roundtrip failed for {src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get_path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn builder_and_pretty() {
        let mut o = Json::obj();
        o.set("speedup", 2.32).set("name", "kernel").set("ok", true);
        let text = o.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back.get("speedup").unwrap().as_f64(), Some(2.32));
        assert_eq!(back.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(40.0).to_string_compact(), "40");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
