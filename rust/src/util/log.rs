//! Leveled stderr logging: global verbosity switch, `KF_LOG` env
//! override, monotonic-ish elapsed timestamps and module targets.
//!
//! Each line looks like
//!
//! ```text
//! [   0.412s WARN  kernelfoundry::service] queue full, rejecting job
//! ```
//!
//! The timestamp is seconds since the first log call (monotonic clock, so
//! it never jumps backwards). Verbosity resolves as: `KF_LOG` env var if
//! set (`error | warn | info | debug`, or `0`–`3`), else the level last
//! passed to [`set_level`] (the CLI's `--verbose`/`--quiet` flags), else
//! `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or data-affecting problems.
    Error = 0,
    /// Degraded but continuing.
    Warn = 1,
    /// Normal operational messages (default).
    Info = 2,
    /// Per-step detail for debugging.
    Debug = 3,
}

impl Level {
    /// Parse a `KF_LOG` value; `None` for unrecognized text.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "0" => Some(Level::Error),
            "warn" | "warning" | "1" => Some(Level::Warn),
            "info" | "2" => Some(Level::Info),
            "debug" | "3" => Some(Level::Debug),
            _ => None,
        }
    }
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // Info

fn env_level() -> Option<Level> {
    static ENV: OnceLock<Option<Level>> = OnceLock::new();
    *ENV.get_or_init(|| std::env::var("KF_LOG").ok().as_deref().and_then(Level::parse))
}

fn start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the global verbosity (overridden by `KF_LOG` when that is set).
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// Would a message at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    let threshold = match env_level() {
        Some(env) => env as u8,
        None => VERBOSITY.load(Ordering::Relaxed),
    };
    (level as u8) <= threshold
}

/// Emit one line to stderr: elapsed time, level tag, module target, text.
/// Prefer the `log_info!`/`log_warn!`/`log_debug!` macros, which fill in
/// `target` from `module_path!`.
pub fn log(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        let elapsed = start().elapsed().as_secs_f64();
        eprintln!("[{elapsed:>8.3}s {tag} {target}] {msg}");
    }
}

/// Log at info level, tagged with the calling module's path.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*)) };
}

/// Log at warn level, tagged with the calling module's path.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*)) };
}

/// Log at debug level, tagged with the calling module's path.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parses_kf_log_values() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("1"), Some(Level::Warn));
        assert_eq!(Level::parse("loud"), None);
    }
}
