//! Infrastructure substrate.
//!
//! The build environment has no network access and only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (serde,
//! rand, clap, criterion, proptest, tokio) are unavailable. This module
//! provides small, well-tested in-repo replacements (see DESIGN.md §2,
//! substitution table).

pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod textdiff;
pub mod yamlite;
