//! Infrastructure substrate.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (serde, rand, clap, criterion, proptest, tokio, anyhow, thiserror) are
//! unavailable. This module provides small, well-tested in-repo
//! replacements (see DESIGN.md §2, substitution table). The optional
//! `pjrt` feature is the sole exception: it reintroduces the `xla` crate
//! for real artifact execution.

pub mod cli;
pub mod error;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod textdiff;
pub mod yamlite;
