//! Micro property-testing framework (replacement for `proptest`,
//! unavailable offline).
//!
//! Provides seeded random-input generation, a fixed number of cases per
//! property, and greedy input shrinking for integer/vec generators. Used
//! by the coordinator-invariant property tests (archive insertion,
//! selection, gradient bounds, routing/batching).

use crate::util::rng::Rng;

/// Number of cases per property (override with `KF_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("KF_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// A generator of random values with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values, tried in order during shrinking.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` against `cases` random inputs from `gen`. On failure,
/// greedily shrinks and panics with the minimal counterexample found.
pub fn check<G: Gen>(seed: u64, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    check_cases(seed, default_cases(), gen, prop)
}

pub fn check_cases<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(gen, value, &prop);
            panic!(
                "property failed (seed {seed}, case {case})\nminimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut value: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut advanced = false;
        for candidate in gen.shrink(&value) {
            if !prop(&candidate) {
                value = candidate;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    value
}

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64In(pub f64, pub f64);

impl Gen for F64In {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        self.0 + rng.f64() * (self.1 - self.0)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v != self.0 {
            vec![self.0, self.0 + (*v - self.0) / 2.0]
        } else {
            vec![]
        }
    }
}

/// Vector of values from an element generator, length in [0, max_len].
pub struct VecOf<G: Gen>(pub G, pub usize);

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.below(self.1 + 1);
        (0..len).map(|_| self.0.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
            // Shrink one element.
            for cand in self.0.shrink(&v[0]) {
                let mut copy = v.clone();
                copy[0] = cand;
                out.push(copy);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(1, &UsizeIn(0, 100), |v| *v <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample: 51")]
    fn failing_property_shrinks_to_boundary() {
        // Property "v <= 50" fails for 51..=100; shrinking should land on 51.
        check(2, &UsizeIn(0, 100), |v| *v <= 50);
    }

    #[test]
    fn vec_generator_produces_varied_lengths() {
        let mut rng = Rng::new(3);
        let gen = VecOf(UsizeIn(0, 9), 8);
        let lens: Vec<usize> = (0..64).map(|_| gen.generate(&mut rng).len()).collect();
        assert!(lens.iter().any(|l| *l == 0));
        assert!(lens.iter().any(|l| *l >= 6));
    }

    #[test]
    fn pair_shrinks_both_sides() {
        let gen = PairOf(UsizeIn(0, 10), F64In(0.0, 1.0));
        let shrunk = gen.shrink(&(10, 0.5));
        assert!(shrunk.iter().any(|(a, _)| *a < 10));
        assert!(shrunk.iter().any(|(_, b)| *b < 0.5));
    }
}
