//! Deterministic pseudo-random number generation (PCG64-DXSM style).
//!
//! Replaces the `rand` crate (unavailable offline). All stochastic pieces
//! of the framework (simllm sampling, selection strategies, hwsim noise)
//! take a `&mut Rng`, so every experiment is reproducible from a seed —
//! which also stands in for the paper's temperature-controlled LLM
//! sampling in a controlled way.

/// A 128-bit-state PCG generator with DXSM output permutation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream: same seed, different sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Rng {
        let mut rng = Rng {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive a child generator from the current state.
    ///
    /// The child depends on how many draws preceded the split, so two
    /// splits with the same label at different points yield different
    /// streams. Do NOT use this for anything that must be independent of
    /// evaluation order (e.g. the eval pipeline's verdict streams — the
    /// dist determinism contract); derive those with [`Rng::with_stream`]
    /// from stable identifiers instead.
    pub fn split(&mut self, label: u64) -> Rng {
        Rng::with_stream(self.next_u64() ^ label, label.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        // DXSM output function.
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's method without bias correction is fine for our n << 2^64,
        // but do the widening-multiply rejection anyway for exactness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal noise factor with multiplicative sigma (e.g. 0.03 ≈ 3% jitter).
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Pick an element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Weighted sample; weights need not be normalized. Zero/negative
    /// weights are treated as zero. Falls back to uniform if all weights
    /// are zero.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w.max(0.0);
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [0usize; 5];
        for _ in 0..5000 {
            seen[r.below(5)] += 1;
        }
        for (i, c) in seen.iter().enumerate() {
            assert!(*c > 800, "bucket {i} count {c}");
        }
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.4..3.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
