//! Descriptive statistics used by the benchmarking methodology (App. B.2)
//! and the metrics layer (§4).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean over strictly positive values; non-positive entries are
/// skipped (matching how the paper reports geometric speedups over kernels
/// that all ran). Returns 0.0 if nothing qualifies.
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|x| **x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        0.0
    } else {
        (logs.iter().sum::<f64>() / logs.len() as f64).exp()
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolation percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Coefficient of variation (stddev / mean); 0 when mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Summary of a timing sample, used by the App. B.2 harness.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p5: f64,
    pub p95: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary {
        n: xs.len(),
        mean: mean(xs),
        median: median(xs),
        std: stddev(xs),
        min: if xs.is_empty() { 0.0 } else { min(xs) },
        max: if xs.is_empty() { 0.0 } else { max(xs) },
        p5: percentile(xs, 5.0),
        p95: percentile(xs, 95.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_geomean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_skips_nonpositive() {
        let g = geomean(&[0.0, -1.0, 4.0, 1.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = summarize(&xs);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!(s.p5 <= s.median && s.median <= s.p95);
    }
}
