//! SEARCH/REPLACE diff application (§3.5).
//!
//! The meta-prompter "prescribes targeted updates as SEARCH/REPLACE diffs
//! restricted to the evolvable regions". This module parses and applies
//! that diff format:
//!
//! ```text
//! <<<<<<< SEARCH
//! old text
//! =======
//! new text
//! >>>>>>> REPLACE
//! ```

/// One parsed SEARCH/REPLACE hunk.
#[derive(Debug, Clone, PartialEq)]
pub struct Hunk {
    pub search: String,
    pub replace: String,
}

#[derive(Debug, PartialEq)]
pub enum DiffError {
    Malformed(String),
    NotFound(String),
    Ambiguous { snippet: String, count: usize },
}

impl std::fmt::Display for DiffError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DiffError::Malformed(m) => write!(f, "malformed diff: {m}"),
            DiffError::NotFound(s) => write!(f, "search text not found: {s:?}"),
            DiffError::Ambiguous { snippet, count } => {
                write!(f, "search text is ambiguous ({count} matches): {snippet:?}")
            }
        }
    }
}

impl std::error::Error for DiffError {}

/// Parse a diff document containing zero or more hunks.
pub fn parse_hunks(diff: &str) -> Result<Vec<Hunk>, DiffError> {
    let mut hunks = Vec::new();
    let mut lines = diff.lines().peekable();
    while let Some(line) = lines.next() {
        if !line.trim_start().starts_with("<<<<<<< SEARCH") {
            continue;
        }
        let mut search = String::new();
        let mut replace = String::new();
        let mut found_sep = false;
        let mut closed = false;
        for inner in lines.by_ref() {
            if inner.trim_start().starts_with("=======") && !found_sep {
                found_sep = true;
            } else if inner.trim_start().starts_with(">>>>>>> REPLACE") {
                closed = true;
                break;
            } else if found_sep {
                replace.push_str(inner);
                replace.push('\n');
            } else {
                search.push_str(inner);
                search.push('\n');
            }
        }
        if !found_sep || !closed {
            return Err(DiffError::Malformed(
                "hunk missing ======= or >>>>>>> REPLACE".into(),
            ));
        }
        hunks.push(Hunk {
            search: search.trim_end_matches('\n').to_string(),
            replace: replace.trim_end_matches('\n').to_string(),
        });
    }
    Ok(hunks)
}

/// Apply one hunk: the search text must occur exactly once.
pub fn apply_hunk(text: &str, hunk: &Hunk) -> Result<String, DiffError> {
    if hunk.search.is_empty() {
        return Err(DiffError::Malformed("empty SEARCH section".into()));
    }
    let count = text.matches(&hunk.search).count();
    match count {
        0 => Err(DiffError::NotFound(snippet(&hunk.search))),
        1 => Ok(text.replacen(&hunk.search, &hunk.replace, 1)),
        _ => Err(DiffError::Ambiguous {
            snippet: snippet(&hunk.search),
            count,
        }),
    }
}

/// Apply all hunks in order; stops at the first failure.
pub fn apply_all(text: &str, hunks: &[Hunk]) -> Result<String, DiffError> {
    let mut cur = text.to_string();
    for h in hunks {
        cur = apply_hunk(&cur, h)?;
    }
    Ok(cur)
}

fn snippet(s: &str) -> String {
    let s: String = s.chars().take(60).collect();
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIFF: &str = "\
<<<<<<< SEARCH
prioritize compute
=======
prioritize memory bandwidth utilization before compute optimization
>>>>>>> REPLACE
";

    #[test]
    fn parse_and_apply() {
        let hunks = parse_hunks(DIFF).unwrap();
        assert_eq!(hunks.len(), 1);
        let out = apply_all("strategy: prioritize compute.\n", &hunks).unwrap();
        assert!(out.contains("memory bandwidth utilization"));
        assert!(!out.contains("prioritize compute."));
    }

    #[test]
    fn multiple_hunks_in_order() {
        let diff = format!("{DIFF}\n<<<<<<< SEARCH\nbandwidth utilization\n=======\nBW use\n>>>>>>> REPLACE\n");
        let hunks = parse_hunks(&diff).unwrap();
        assert_eq!(hunks.len(), 2);
        let out = apply_all("prioritize compute", &hunks).unwrap();
        assert!(out.contains("BW use"));
    }

    #[test]
    fn not_found_and_ambiguous() {
        let hunks = parse_hunks(DIFF).unwrap();
        assert!(matches!(
            apply_all("nothing here", &hunks),
            Err(DiffError::NotFound(_))
        ));
        assert!(matches!(
            apply_all("prioritize compute prioritize compute", &hunks),
            Err(DiffError::Ambiguous { count: 2, .. })
        ));
    }

    #[test]
    fn malformed_rejected() {
        assert!(parse_hunks("<<<<<<< SEARCH\nabc\n").is_err());
    }

    #[test]
    fn no_hunks_is_ok() {
        assert!(parse_hunks("plain text").unwrap().is_empty());
    }
}
