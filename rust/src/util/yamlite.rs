//! Parser for the YAML subset used by task configuration files (App. C:
//! "a config file in YAML format containing hyperparameters").
//!
//! Supported: nested mappings by 2-space indentation, block sequences
//! (`- item`), inline scalars (string / number / bool / null), quoted
//! strings, comments (`#`), and flow sequences (`[a, b]`). This covers
//! every config file in the repo; anchors, multi-line scalars and flow
//! mappings are intentionally out of scope.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for YamlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "yaml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

/// Parse a YAML document into the shared `Json` value model.
pub fn parse(input: &str) -> Result<Json, YamlError> {
    let lines: Vec<Line> = input
        .lines()
        .enumerate()
        .filter_map(|(no, raw)| {
            let without_comment = strip_comment(raw);
            let trimmed = without_comment.trim_end();
            if trimmed.trim().is_empty() {
                return None;
            }
            let indent = trimmed.len() - trimmed.trim_start().len();
            Some(Line {
                no: no + 1,
                indent,
                text: trimmed.trim_start().to_string(),
            })
        })
        .collect();
    if lines.is_empty() {
        return Ok(Json::obj());
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(YamlError {
            line: lines[pos].no,
            msg: "unexpected dedent/indent structure".into(),
        });
    }
    Ok(v)
}

struct Line {
    no: usize,
    indent: usize,
    text: String,
}

fn strip_comment(s: &str) -> String {
    let mut out = String::new();
    let mut in_quote: Option<char> = None;
    for c in s.chars() {
        match (c, in_quote) {
            ('#', None) => break,
            ('"', None) => in_quote = Some('"'),
            ('\'', None) => in_quote = Some('\''),
            ('"', Some('"')) | ('\'', Some('\'')) => in_quote = None,
            _ => {}
        }
        out.push(c);
    }
    out
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_seq(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_seq(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent && lines[*pos].text.starts_with('-') {
        let line = &lines[*pos];
        let rest = line.text[1..].trim_start().to_string();
        *pos += 1;
        if rest.is_empty() {
            // Nested block under the dash.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Json::Null);
            }
        } else if rest.contains(": ") || rest.ends_with(':') {
            // Inline mapping start: "- key: value" — the rest of the map is
            // indented deeper than the dash.
            let mut map = BTreeMap::new();
            insert_kv(&mut map, &rest, lines, pos, line.no, indent + 2)?;
            while *pos < lines.len()
                && lines[*pos].indent > indent
                && !lines[*pos].text.starts_with("- ")
            {
                let text = lines[*pos].text.clone();
                let no = lines[*pos].no;
                let inner_indent = lines[*pos].indent;
                *pos += 1;
                insert_kv(&mut map, &text, lines, pos, no, inner_indent)?;
            }
            items.push(Json::Obj(map));
        } else {
            items.push(scalar(&rest));
        }
    }
    Ok(Json::Arr(items))
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() && lines[*pos].indent == indent && !lines[*pos].text.starts_with("- ")
    {
        let text = lines[*pos].text.clone();
        let no = lines[*pos].no;
        *pos += 1;
        insert_kv(&mut map, &text, lines, pos, no, indent)?;
    }
    Ok(Json::Obj(map))
}

fn insert_kv(
    map: &mut BTreeMap<String, Json>,
    text: &str,
    lines: &[Line],
    pos: &mut usize,
    line_no: usize,
    indent: usize,
) -> Result<(), YamlError> {
    let colon = find_key_colon(text).ok_or(YamlError {
        line: line_no,
        msg: format!("expected 'key: value', got '{text}'"),
    })?;
    let key = unquote(text[..colon].trim());
    let rest = text[colon + 1..].trim();
    if rest.is_empty() {
        // Nested block (map or sequence) or empty value.
        if *pos < lines.len() && lines[*pos].indent > indent {
            let child_indent = lines[*pos].indent;
            let v = parse_block(lines, pos, child_indent)?;
            map.insert(key, v);
        } else {
            map.insert(key, Json::Null);
        }
    } else {
        map.insert(key, scalar(rest));
    }
    Ok(())
}

/// Find the colon that separates key from value (respecting quotes).
fn find_key_colon(s: &str) -> Option<usize> {
    let bytes = s.as_bytes();
    let mut in_quote: Option<u8> = None;
    for (i, &b) in bytes.iter().enumerate() {
        match (b, in_quote) {
            (b'"', None) => in_quote = Some(b'"'),
            (b'\'', None) => in_quote = Some(b'\''),
            (b'"', Some(b'"')) | (b'\'', Some(b'\'')) => in_quote = None,
            (b':', None) => {
                if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

/// Interpret an inline scalar (or flow sequence).
fn scalar(s: &str) -> Json {
    let s = s.trim();
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Json::Arr(vec![]);
        }
        return Json::Arr(split_flow(inner).iter().map(|p| scalar(p)).collect());
    }
    if s.starts_with('"') || s.starts_with('\'') {
        return Json::Str(unquote(s));
    }
    match s {
        "null" | "~" | "" => return Json::Null,
        "true" | "True" => return Json::Bool(true),
        "false" | "False" => return Json::Bool(false),
        _ => {}
    }
    if let Ok(n) = s.parse::<f64>() {
        if !s.contains(|c: char| c.is_ascii_alphabetic() && c != 'e' && c != 'E') || s.parse::<i64>().is_ok() {
            return Json::Num(n);
        }
    }
    Json::Str(s.to_string())
}

/// Split a flow sequence body on top-level commas.
fn split_flow(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    let mut in_quote: Option<char> = None;
    for c in s.chars() {
        match (c, in_quote) {
            ('"', None) => in_quote = Some('"'),
            ('\'', None) => in_quote = Some('\''),
            ('"', Some('"')) | ('\'', Some('\'')) => in_quote = None,
            ('[', None) => depth += 1,
            (']', None) => depth -= 1,
            (',', None) if depth == 0 => {
                parts.push(cur.trim().to_string());
                cur.clear();
                continue;
            }
            _ => {}
        }
        cur.push(c);
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_maps_and_scalars() {
        let y = "evolution:\n  max_generations: 40\n  selection: curiosity\n  enabled: true\nname: \"demo task\"\n";
        let v = parse(y).unwrap();
        assert_eq!(
            v.get_path("evolution.max_generations").unwrap().as_i64(),
            Some(40)
        );
        assert_eq!(
            v.get_path("evolution.selection").unwrap().as_str(),
            Some("curiosity")
        );
        assert_eq!(v.get("name").unwrap().as_str(), Some("demo task"));
    }

    #[test]
    fn sequences_block_and_flow() {
        let y = "models:\n  - gpt-4.1\n  - gpt-5-mini\nbins: [4, 4, 4]\n";
        let v = parse(y).unwrap();
        let models = v.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].as_str(), Some("gpt-4.1"));
        let bins = v.get("bins").unwrap().as_arr().unwrap();
        assert_eq!(bins.iter().filter_map(|b| b.as_i64()).sum::<i64>(), 12);
    }

    #[test]
    fn sequence_of_maps() {
        let y = "workers:\n  - kind: compile\n    count: 2\n  - kind: execute\n    count: 4\n";
        let v = parse(y).unwrap();
        let ws = v.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[1].get("kind").unwrap().as_str(), Some("execute"));
        assert_eq!(ws[1].get("count").unwrap().as_i64(), Some(4));
    }

    #[test]
    fn comments_and_blanks() {
        let y = "# header\na: 1  # trailing\n\nb: 'x # not comment'\n";
        let v = parse(y).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x # not comment"));
    }

    #[test]
    fn error_on_bad_line() {
        assert!(parse("just a line without colon\n").is_err());
    }
}
