//! Chaos end-to-end: a real daemon under the committed fault plan
//! (`chaos_plan.txt`) must drive every job to a terminal state — retried
//! units commit exactly once, hung units hit their deadline and
//! recover, poison units quarantine, fan-out jobs degrade to `partial`
//! naming the dead lane, and nothing is ever lost.
//!
//! When `KF_E2E_FAULT_DIR` is set (CI), the journal / db / trace files
//! are left there for `scripts/check_faults.py`, which independently
//! folds the journal and asserts every dispatched unit reached exactly
//! one terminal verdict.

use kernelfoundry::dist::Database;
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::obs::{stage, TraceSink};
use kernelfoundry::service::{
    cache, proto, Client, DeviceTarget, FaultPlan, GuardConfig, JobSpec, KernelService, Request,
    Server, ServiceConfig,
};
use kernelfoundry::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The committed plan this e2e (and the CI chaos step) runs under.
fn plan_path() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/chaos_plan.txt"))
}

/// Artifact location: `KF_E2E_FAULT_DIR` when set (CI inspects and
/// uploads the fault logs after the suite), else the system temp dir.
fn fault_dir() -> (PathBuf, bool) {
    match std::env::var("KF_E2E_FAULT_DIR") {
        Ok(dir) => {
            let dir = PathBuf::from(dir);
            let _ = std::fs::create_dir_all(&dir);
            (dir, true)
        }
        Err(_) => (std::env::temp_dir(), false),
    }
}

fn spec_for(task: &str, device: &str, seed: u64) -> JobSpec {
    let mut spec = JobSpec::catalog(task, device);
    spec.iters = 3;
    spec.population = 2;
    spec.seed = seed;
    spec
}

fn submit(client: &mut Client, spec: JobSpec) -> u64 {
    let resp = client.request(&Request::Submit(spec)).expect("submit rpc");
    assert!(proto::response_ok(&resp), "submit failed: {resp}");
    resp.get("job_id").and_then(|v| v.as_usize()).expect("job_id") as u64
}

/// Poll `status` to ANY terminal state (the chaos run produces `done`,
/// `partial` and `failed` jobs by design) and return it.
fn poll_terminal(client: &mut Client, id: u64) -> String {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let resp = client.request(&Request::Status(id)).expect("status rpc");
        assert!(proto::response_ok(&resp), "status failed: {resp}");
        let state = resp.get("state").and_then(|s| s.as_str()).unwrap().to_string();
        if matches!(state.as_str(), "done" | "partial" | "failed" | "cancelled") {
            return state;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in state {state}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Fetch the full result object (the `result` verb serves any finished
/// job, including failed and partial ones, with `results` + `errors`).
fn fetch_result(client: &mut Client, id: u64) -> Json {
    let resp = client.request(&Request::Result(id)).expect("result rpc");
    assert!(proto::response_ok(&resp), "result failed: {resp}");
    resp
}

/// Devices that delivered a result object for this job.
fn result_devices(result: &Json) -> Vec<String> {
    result
        .get("results")
        .and_then(|r| r.as_arr())
        .map(|rs| {
            rs.iter()
                .filter_map(|r| r.get("device").and_then(|d| d.as_str()))
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default()
}

/// The error string recorded for one device's unit (empty if none).
fn error_for(result: &Json, device: &str) -> String {
    result
        .get("errors")
        .and_then(|e| e.as_arr())
        .and_then(|errs| {
            errs.iter()
                .find(|e| e.get("device").and_then(|d| d.as_str()) == Some(device))
        })
        .and_then(|e| e.get("error").and_then(|m| m.as_str()))
        .unwrap_or("")
        .to_string()
}

/// Whether the (single) result object carries a correct kernel — only
/// correct verdicts are write-through persisted as db rows.
fn is_correct(result: &Json) -> bool {
    result
        .get("results")
        .and_then(|r| r.as_arr())
        .and_then(|rs| rs.first())
        .and_then(|r| r.get("correct"))
        .and_then(|c| c.as_bool())
        == Some(true)
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| {
            l.strip_prefix(name)
                .and_then(|rest| rest.strip_prefix(' '))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or_else(|| panic!("metric {name} missing from exposition:\n{text}"))
}

fn rows_for_key(db_path: &Path, key: &str) -> usize {
    let db = Database::new();
    db.load_tolerant(db_path).expect("db loads");
    db.rows().iter().filter(|r| r.run == key).count()
}

/// The whole chaos scenario in one flow (one daemon, five jobs), so the
/// lane states evolve exactly as the committed plan scripts them.
#[test]
fn chaos_plan_drives_every_job_to_a_terminal_state() {
    let (dir, keep) = fault_dir();
    let journal = dir.join("kf_e2e_chaos.journal.jsonl");
    let db = dir.join("kf_e2e_chaos.db.jsonl");
    let trace = dir.join("kf_e2e_chaos.trace.jsonl");
    for p in [&journal, &db, &trace] {
        let _ = std::fs::remove_file(p);
    }

    let plan = FaultPlan::load(&plan_path()).expect("committed chaos plan parses");
    assert_eq!(plan.len(), 3, "chaos_plan.txt drifted from the scenario");
    let service = KernelService::start(ServiceConfig {
        devices: vec![DeviceProfile::lnl(), DeviceProfile::b580(), DeviceProfile::a6000()],
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 16,
        db_path: Some(db.clone()),
        journal_path: Some(journal.clone()),
        trace_path: Some(trace.clone()),
        guard: GuardConfig {
            max_retries: 2,
            unit_deadline: Some(Duration::from_millis(2500)),
            trip_threshold: 2,
            retry_backoff: Duration::from_millis(50),
            lane_cooldown: Duration::from_millis(1500),
        },
        fault_plan: Some(plan),
        ..ServiceConfig::default()
    })
    .expect("service starts");
    let mut server = Server::start(Arc::clone(&service), "127.0.0.1:0").expect("server binds");
    let mut client = Client::connect(&server.addr().to_string()).expect("client connects");

    // J1 — transient compile fault on b580: one retry, then commits.
    let j1_spec = spec_for("20_LeakyReLU", "b580", 1);
    let j1 = submit(&mut client, j1_spec.clone());
    assert_eq!(poll_terminal(&mut client, j1), "done", "retry must recover the unit");
    let j1_result = fetch_result(&mut client, j1);

    // J2 — 10s exec hang on lnl vs a 2.5s unit deadline: the deadline
    // cancels the attempt, the retry runs clean.
    let j2_spec = spec_for("21_Sigmoid", "lnl", 2);
    let j2 = submit(&mut client, j2_spec.clone());
    assert_eq!(poll_terminal(&mut client, j2), "done", "deadline + retry must recover");
    let j2_result = fetch_result(&mut client, j2);

    // J3 — the dead lane: retries exhaust, the unit quarantines with a
    // deterministic failure verdict, and the breaker trips open.
    let j3 = submit(&mut client, spec_for("20_LeakyReLU", "a6000", 3));
    assert_eq!(poll_terminal(&mut client, j3), "failed");
    let j3_err = error_for(&fetch_result(&mut client, j3), "a6000");
    assert!(
        j3_err.contains("quarantined after 3 attempts"),
        "poison verdict names the exhausted budget: {j3_err}"
    );

    // J4 — fan-out across the fleet with a6000 down: the job degrades
    // to `partial`, the failed unit names the dead lane, the healthy
    // units still deliver.
    let mut fan = spec_for("20_LeakyReLU", "b580", 4);
    fan.device = DeviceTarget::FanOut;
    let j4 = submit(&mut client, fan);
    assert_eq!(
        poll_terminal(&mut client, j4),
        "partial",
        "fan-out must degrade to the surviving subset, not fail outright"
    );
    let j4_result = fetch_result(&mut client, j4);
    let mut j4_devices = result_devices(&j4_result);
    j4_devices.sort_unstable();
    assert_eq!(j4_devices, vec!["b580", "lnl"], "healthy lanes delivered: {j4_result}");
    let j4_err = error_for(&j4_result, "a6000");
    assert!(j4_err.contains("a6000"), "partial verdict names the dead lane: {j4_err}");

    // J5 — a routed job aimed straight at the dead lane: either the
    // open breaker reroutes it to a healthy peer (done, elsewhere) or a
    // half-open probe burns its budget (quarantined). Lost is the only
    // wrong answer.
    let j5 = submit(&mut client, spec_for("20_LeakyReLU", "a6000", 5));
    match poll_terminal(&mut client, j5).as_str() {
        "done" => {
            let j5_result = fetch_result(&mut client, j5);
            let devices = result_devices(&j5_result);
            assert_eq!(devices.len(), 1, "{j5_result}");
            assert_ne!(
                devices[0], "a6000",
                "a done unit must have been rerouted off the dead lane: {j5_result}"
            );
        }
        "failed" => {
            let j5_err = fetch_result(&mut client, j5).to_string();
            assert!(
                j5_err.contains("quarantined") || j5_err.contains("circuit breaker"),
                "a failed routed unit must carry the quarantine/breaker verdict: {j5_err}"
            );
        }
        other => panic!("job {j5} ended in unexpected state {other}"),
    }

    // Fleet + journal accounting: the dead lane is visibly open (or
    // probing), retries and the quarantine are counted, nothing lost.
    let stats = client.request(&Request::Stats).expect("stats rpc");
    let fleet = stats.get("fleet").unwrap().as_arr().unwrap();
    let a6000 = fleet
        .iter()
        .find(|l| l.get("device").and_then(|d| d.as_str()) == Some("a6000"))
        .unwrap();
    assert!(
        matches!(a6000.get("state").and_then(|s| s.as_str()), Some("open") | Some("half_open")),
        "dead lane's breaker is not closed: {stats}"
    );
    assert!(a6000.get("quarantined").unwrap().as_f64().unwrap() >= 1.0, "{stats}");
    assert_eq!(stats.get_path("journal.lost_jobs").unwrap().as_f64(), Some(0.0), "{stats}");

    let resp = client.request(&Request::Metrics(None)).expect("metrics rpc");
    let text = resp.get("prometheus").unwrap().as_str().unwrap().to_string();
    assert!(metric_value(&text, "kf_retry_total") >= 5.0, "{text}");
    assert!(metric_value(&text, "kf_units_quarantined_total") >= 1.0, "{text}");
    assert!(metric_value(&text, "kf_deadline_exceeded_total") >= 1.0, "{text}");
    assert!(metric_value(&text, "kf_faults_injected_total") >= 6.0, "{text}");

    server.shutdown();
    server.wait();
    service.stop();

    // Exactly one verdict row per recovered *correct* unit (only
    // correct kernels are write-through persisted), never more — and
    // none at all for the poison unit.
    let j1_rows = rows_for_key(&db, &cache::cache_key(&j1_spec, "b580"));
    assert_eq!(j1_rows, usize::from(is_correct(&j1_result)), "retried unit commits once");
    let j2_rows = rows_for_key(&db, &cache::cache_key(&j2_spec, "lnl"));
    assert_eq!(j2_rows, usize::from(is_correct(&j2_result)), "deadline-retried unit commits once");
    assert_eq!(
        rows_for_key(&db, &cache::cache_key(&spec_for("20_LeakyReLU", "a6000", 3), "a6000")),
        0,
        "a quarantined unit never publishes a row"
    );

    // The trace sink carries the fault-tolerance lifecycle stages.
    let j1_stages: Vec<String> =
        TraceSink::timeline(&trace, j1).iter().map(|e| e.stage.clone()).collect();
    assert!(j1_stages.contains(&stage::RETRIED.to_string()), "{j1_stages:?}");
    let j3_stages: Vec<String> =
        TraceSink::timeline(&trace, j3).iter().map(|e| e.stage.clone()).collect();
    assert_eq!(
        j3_stages.iter().filter(|s| *s == stage::QUARANTINED).count(),
        1,
        "exactly one quarantine verdict for the poison unit: {j3_stages:?}"
    );

    if !keep {
        for p in [&journal, &db, &trace] {
            let _ = std::fs::remove_file(p);
        }
    }
}
