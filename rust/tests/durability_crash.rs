//! Crash-injection durability tests: a real `kernelfoundry daemon`
//! subprocess is aborted at journal/commit fail-points (`KF_FAILPOINT`),
//! restarted against the same journal, and must replay every job with
//! exactly one verdict row per unit.
//!
//! The exactly-once assertion leans on the determinism contract:
//! verdicts are a pure function of (seed, genome id), so an at-least-
//! once re-run after a crash is publication-equivalent to the attempt
//! the crash destroyed — the slot-commit protocol then guarantees the
//! *row* is published once.

use kernelfoundry::dist::Database;
use kernelfoundry::service::journal::{Journal, JournalRecord};
use kernelfoundry::service::{cache, failpoint, proto, Client, JobSpec, Request};
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// A daemon subprocess plus the stdout reader thread that keeps the
/// child's pipe drained (an unread pipe would wedge or EPIPE it).
struct Daemon {
    child: Child,
    addr: String,
    _stdout: std::thread::JoinHandle<()>,
}

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_kernelfoundry")
}

/// Spawn `kernelfoundry daemon` with the given journal/db/TTL and an
/// optional armed fail-point; parse the listen address from stdout.
fn spawn_daemon(journal: &Path, db: &Path, ttl_secs: u64, failpoints: &str) -> Daemon {
    spawn_daemon_with(journal, db, ttl_secs, failpoints, &[])
}

/// [`spawn_daemon`] with extra CLI flags (fault plans, retry knobs).
fn spawn_daemon_with(
    journal: &Path,
    db: &Path,
    ttl_secs: u64,
    failpoints: &str,
    extra: &[&str],
) -> Daemon {
    let mut cmd = Command::new(bin());
    cmd.args([
        "daemon",
        "--addr",
        "127.0.0.1:0",
        "--devices",
        "b580",
        "--compile-workers",
        "1",
        "--exec-workers",
        "2",
        "--journal",
        journal.to_str().unwrap(),
        "--db",
        db.to_str().unwrap(),
        "--lease-ttl",
        &ttl_secs.to_string(),
    ])
    .args(extra)
    .env(failpoint::ENV_VAR, failpoints)
    .stdout(Stdio::piped())
    .stderr(Stdio::null());
    let mut child = cmd.spawn().expect("daemon spawns");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);

    let mut addr = String::new();
    let mut line = String::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    while addr.is_empty() {
        assert!(Instant::now() < deadline, "daemon never announced its address");
        line.clear();
        let n = reader.read_line(&mut line).expect("reading daemon stdout");
        assert!(n > 0, "daemon exited before announcing its address");
        if let Some(rest) = line.split("listening on ").nth(1) {
            addr = rest.split_whitespace().next().unwrap_or("").to_string();
        }
    }
    // Keep draining so the child never blocks on a full pipe.
    let handle = std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    Daemon {
        child,
        addr,
        _stdout: handle,
    }
}

impl Daemon {
    fn client(&self) -> Client {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match Client::connect(&self.addr) {
                Ok(c) => return c,
                Err(e) => {
                    assert!(Instant::now() < deadline, "connecting to {}: {e}", self.addr);
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    /// Block until the child exits (e.g. an armed fail-point aborted
    /// it); panics if it is still alive after the timeout.
    fn wait_for_exit(&mut self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        loop {
            if self.child.try_wait().expect("try_wait").is_some() {
                return;
            }
            assert!(Instant::now() < deadline, "daemon did not exit in {timeout:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Clean RPC shutdown: the daemon drains, releases its lease, exits.
    fn shutdown(&mut self) {
        let mut client = self.client();
        let resp = client.request(&Request::Shutdown).expect("shutdown rpc");
        assert!(proto::response_ok(&resp), "{resp}");
        self.wait_for_exit(Duration::from_secs(60));
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn temp_paths(name: &str) -> (PathBuf, PathBuf) {
    let dir = std::env::temp_dir();
    let journal = dir.join(format!("kf_crash_{}_{}.journal.jsonl", name, std::process::id()));
    let db = dir.join(format!("kf_crash_{}_{}.db.jsonl", name, std::process::id()));
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
    (journal, db)
}

fn crash_spec() -> JobSpec {
    let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
    spec.iters = 3;
    spec.population = 2;
    spec.seed = 11;
    spec
}

/// Submit and return the job id (the daemon may abort right after, so
/// the submit response itself must still be well-formed).
fn submit(client: &mut Client, spec: JobSpec) -> u64 {
    let resp = client.request(&Request::Submit(spec)).expect("submit rpc");
    assert!(proto::response_ok(&resp), "submit failed: {resp}");
    resp.get("job_id").and_then(|v| v.as_usize()).expect("job_id") as u64
}

fn poll_done(client: &mut Client, id: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let resp = client.request(&Request::Status(id)).expect("status rpc");
        let state = resp.get("state").and_then(|s| s.as_str()).unwrap_or("").to_string();
        if state == "done" {
            return;
        }
        assert!(
            !matches!(state.as_str(), "failed" | "cancelled"),
            "job {id} ended {state}: {resp}"
        );
        assert!(Instant::now() < deadline, "job {id} stuck in {state}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn stat_u64(stats: &kernelfoundry::util::json::Json, path: &str) -> u64 {
    stats
        .get_path(path)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing {path} in {stats}")) as u64
}

/// Rows in the db whose run key matches the crashed unit's cache key.
fn rows_for_key(db_path: &Path, key: &str) -> usize {
    if !db_path.exists() {
        return 0;
    }
    let db = Database::new();
    db.load_tolerant(db_path).expect("db loads");
    db.rows().iter().filter(|r| r.run == key).count()
}

/// Crash between the journal Commit marker and the cache row: replay
/// must repair the missing row from the marker — never re-run the job,
/// never publish a second row.
#[test]
fn crash_after_commit_marker_repairs_the_row_exactly_once() {
    let (journal, db) = temp_paths("marker");
    let key = cache::cache_key(&crash_spec(), "b580");

    let mut daemon = spawn_daemon(&journal, &db, 1, "commit.after_marker");
    let mut client = daemon.client();
    let id = submit(&mut client, crash_spec());
    assert_eq!(id, 1);
    // The lane hits the fail-point right after journaling the Commit
    // marker and aborts the whole process: marker durable, row lost.
    daemon.wait_for_exit(Duration::from_secs(120));

    let records = Journal::load_records(&journal).expect("journal readable after abort");
    let commits: Vec<_> = records
        .iter()
        .filter(|r| matches!(r, JournalRecord::Commit { job_id: 1, .. }))
        .collect();
    assert_eq!(commits.len(), 1, "exactly one durable commit marker: {records:?}");
    assert_eq!(rows_for_key(&db, &key), 0, "crash was before the row append");

    // Restart unarmed once the dead owner's lease has expired.
    std::thread::sleep(Duration::from_millis(1500));
    let mut daemon = spawn_daemon(&journal, &db, 1, "");
    let mut client = daemon.client();
    poll_done(&mut client, 1);
    let result = client.request(&Request::Result(1)).expect("result rpc");
    assert!(proto::response_ok(&result), "{result}");

    let stats = client.request(&Request::Stats).expect("stats rpc");
    assert_eq!(stat_u64(&stats, "journal.replayed_jobs"), 1, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.restored_results"), 1, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.requeued_units"), 0, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.lost_jobs"), 0, "{stats}");
    daemon.shutdown();

    assert_eq!(rows_for_key(&db, &key), 1, "slot repair published the row exactly once");
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
}

/// Crash right after the Dispatch record: the unit is in-flight with no
/// commit, so the restart re-runs it (at-least-once) and the re-run's
/// verdict is published exactly once.
#[test]
fn crash_after_dispatch_requeues_and_commits_once() {
    let (journal, db) = temp_paths("dispatch");
    let key = cache::cache_key(&crash_spec(), "b580");

    let mut daemon = spawn_daemon(&journal, &db, 1, "dispatch.after_journal");
    let mut client = daemon.client();
    assert_eq!(submit(&mut client, crash_spec()), 1);
    daemon.wait_for_exit(Duration::from_secs(120));

    let records = Journal::load_records(&journal).expect("journal readable after abort");
    assert!(
        records.iter().any(|r| matches!(r, JournalRecord::Dispatch { job_id: 1, .. })),
        "dispatch was journaled before the crash: {records:?}"
    );
    assert!(
        !records.iter().any(|r| matches!(r, JournalRecord::Commit { .. })),
        "no commit survived the crash: {records:?}"
    );

    std::thread::sleep(Duration::from_millis(1500));
    let mut daemon = spawn_daemon(&journal, &db, 1, "");
    let mut client = daemon.client();
    poll_done(&mut client, 1);

    let stats = client.request(&Request::Stats).expect("stats rpc");
    assert_eq!(stat_u64(&stats, "journal.requeued_units"), 1, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.lost_jobs"), 0, "{stats}");
    daemon.shutdown();

    let records = Journal::load_records(&journal).expect("journal readable");
    let commits = records
        .iter()
        .filter(|r| matches!(r, JournalRecord::Commit { job_id: 1, .. }))
        .count();
    assert_eq!(commits, 1, "the re-run committed exactly once");
    assert_eq!(rows_for_key(&db, &key), 1, "exactly one verdict row for the re-run");
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
}

/// Crash between the Retry journal record and the actual re-dispatch:
/// the restart must neither lose the unit nor double-commit it. The
/// Retry record carries the attempt count forward, so the replayed run
/// starts at attempt 1 — past the `times=1` injected fault — and
/// commits exactly one verdict row.
#[test]
fn crash_between_retry_journal_and_redispatch_commits_once() {
    let (journal, db) = temp_paths("retry");
    let key = cache::cache_key(&crash_spec(), "b580");
    let plan = std::env::temp_dir()
        .join(format!("kf_crash_retry_{}.plan.txt", std::process::id()));
    std::fs::write(&plan, "b580 compile fail times=1\n").unwrap();
    let extra = [
        "--fault-plan",
        plan.to_str().unwrap(),
        "--max-retries",
        "2",
        "--retry-backoff-ms",
        "5",
    ];

    // Attempt 0 hits the injected compile fault; the lane journals the
    // Retry record and the armed fail-point aborts the process before
    // the unit re-enters the queue.
    let mut daemon = spawn_daemon_with(&journal, &db, 1, "retry.after_journal", &extra);
    let mut client = daemon.client();
    assert_eq!(submit(&mut client, crash_spec()), 1);
    daemon.wait_for_exit(Duration::from_secs(120));

    let records = Journal::load_records(&journal).expect("journal readable after abort");
    let retries = records
        .iter()
        .filter(|r| matches!(r, JournalRecord::Retry { job_id: 1, .. }))
        .count();
    assert_eq!(retries, 1, "exactly one durable retry record: {records:?}");
    assert!(
        !records.iter().any(|r| matches!(r, JournalRecord::Commit { .. })),
        "no commit survived the crash: {records:?}"
    );
    assert_eq!(rows_for_key(&db, &key), 0, "crash was before any verdict row");

    // Restart under the same plan: replay requeues the unit at attempt
    // 1, past the times=1 fault, so the re-run is clean.
    std::thread::sleep(Duration::from_millis(1500));
    let mut daemon = spawn_daemon_with(&journal, &db, 1, "", &extra);
    let mut client = daemon.client();
    poll_done(&mut client, 1);

    let stats = client.request(&Request::Stats).expect("stats rpc");
    assert_eq!(stat_u64(&stats, "journal.requeued_units"), 1, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.lost_jobs"), 0, "{stats}");
    daemon.shutdown();

    let records = Journal::load_records(&journal).expect("journal readable");
    let commits = records
        .iter()
        .filter(|r| matches!(r, JournalRecord::Commit { job_id: 1, .. }))
        .count();
    assert_eq!(commits, 1, "the retried unit committed exactly once");
    assert_eq!(rows_for_key(&db, &key), 1, "exactly one verdict row for the retried unit");
    let _ = std::fs::remove_file(&plan);
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
}

/// Crash between the Quarantine journal record and the job-table
/// update: replay must land the unit as failed (the deterministic
/// quarantine verdict) — not re-run it, not lose it.
#[test]
fn crash_at_quarantine_journal_replays_the_failure_verdict() {
    let (journal, db) = temp_paths("quarantine");
    let key = cache::cache_key(&crash_spec(), "b580");
    let plan = std::env::temp_dir()
        .join(format!("kf_crash_quar_{}.plan.txt", std::process::id()));
    std::fs::write(&plan, "b580 * dead\n").unwrap();
    let extra = [
        "--fault-plan",
        plan.to_str().unwrap(),
        "--max-retries",
        "0",
        "--lane-trip-threshold",
        "100",
    ];

    // max-retries 0: the first failure exhausts the budget, the lane
    // journals the Quarantine record and the fail-point aborts before
    // the job table sees the verdict.
    let mut daemon = spawn_daemon_with(&journal, &db, 1, "quarantine.after_journal", &extra);
    let mut client = daemon.client();
    assert_eq!(submit(&mut client, crash_spec()), 1);
    daemon.wait_for_exit(Duration::from_secs(120));

    let records = Journal::load_records(&journal).expect("journal readable after abort");
    assert!(
        records.iter().any(|r| matches!(r, JournalRecord::Quarantine { job_id: 1, .. })),
        "quarantine was journaled before the crash: {records:?}"
    );

    // Restart unarmed and without the plan: if replay wrongly requeued
    // the unit it would now run clean and commit — the assertions below
    // catch exactly that.
    std::thread::sleep(Duration::from_millis(1500));
    let mut daemon = spawn_daemon(&journal, &db, 1, "");
    let mut client = daemon.client();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let resp = client.request(&Request::Status(1)).expect("status rpc");
        let state = resp.get("state").and_then(|s| s.as_str()).unwrap_or("").to_string();
        if state == "failed" {
            break;
        }
        assert!(
            state != "done",
            "quarantined unit must not be re-run to success: {resp}"
        );
        assert!(Instant::now() < deadline, "job 1 stuck in {state}");
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = client.request(&Request::Stats).expect("stats rpc");
    assert_eq!(stat_u64(&stats, "journal.requeued_units"), 0, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.lost_jobs"), 0, "{stats}");
    daemon.shutdown();
    assert_eq!(rows_for_key(&db, &key), 0, "a quarantined unit never publishes a row");
    let _ = std::fs::remove_file(&plan);
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
}

/// Owner leases: a second daemon pointed at a live journal is refused;
/// after a clean shutdown (lease released) a successor starts
/// immediately, without waiting out the TTL.
#[test]
fn second_daemon_is_fenced_until_the_lease_is_released() {
    let (journal, db) = temp_paths("lease");

    // Long TTL: only an explicit release can free the lease in test
    // time, so a successful successor start proves the release path.
    let mut first = spawn_daemon(&journal, &db, 300, "");
    let _client = first.client();

    let out = Command::new(bin())
        .args([
            "daemon",
            "--addr",
            "127.0.0.1:0",
            "--devices",
            "b580",
            "--journal",
            journal.to_str().unwrap(),
            "--lease-ttl",
            "300",
        ])
        .output()
        .expect("second daemon runs");
    assert!(!out.status.success(), "second daemon must be fenced out");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("held by"), "fencing error names the holder: {stderr}");

    first.shutdown();
    let mut successor = spawn_daemon(&journal, &db, 300, "");
    let mut client = successor.client();
    let stats = client.request(&Request::Stats).expect("stats rpc");
    assert_eq!(stats.get_path("journal.enabled").unwrap().as_bool(), Some(true));
    successor.shutdown();
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
}
