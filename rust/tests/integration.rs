//! Integration tests: whole-system flows across coordinator, eval,
//! dist, runtime and config.

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::{openevolve_like, EvolutionEngine};
use kernelfoundry::dist::{ClusterConfig, Database, DbRow, WorkerPool};
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::metrics::{aggregate, TaskResult};
use kernelfoundry::runtime::{Manifest, PjrtBackend};
use kernelfoundry::tasks::catalog;
use std::path::Path;

fn quick_config() -> FoundryConfig {
    let mut c = FoundryConfig::paper_defaults();
    c.evolution.max_generations = 10;
    c.evolution.population = 4;
    c
}

/// Full sweep over a small task set: evolution produces correct kernels
/// with aggregate speedup > 1 on L2 fusion tasks.
#[test]
fn evolution_sweep_over_l2_subset() {
    let tasks: Vec<_> = catalog::kernelbench_l2().into_iter().take(5).collect();
    let mut results = Vec::new();
    for task in &tasks {
        let mut engine = EvolutionEngine::new(
            quick_config(),
            task.clone(),
            ExecBackend::HwSim(DeviceProfile::b580()),
        );
        results.push(engine.run(true).task_result());
    }
    let agg = aggregate(&results);
    assert!(agg.correct_rate >= 0.8, "correct rate {}", agg.correct_rate);
    assert!(agg.avg_speedup > 1.2, "avg speedup {}", agg.avg_speedup);
}

/// Ours vs OpenEvolve-like: with few iterations, the kernel-specific QD
/// machinery converges faster on average (Table 2's 10-iteration gap).
#[test]
fn ours_beats_openevolve_at_low_iterations() {
    let tasks: Vec<_> = catalog::kernelbench_l2().into_iter().take(6).collect();
    let config = quick_config();
    let mut ours_total = 0.0;
    let mut open_total = 0.0;
    for task in &tasks {
        let mut engine = EvolutionEngine::new(
            config.clone(),
            task.clone(),
            ExecBackend::HwSim(DeviceProfile::b580()),
        );
        ours_total += engine.run(false).best_speedup();
        let open = openevolve_like(
            &config,
            task,
            ExecBackend::HwSim(DeviceProfile::b580()),
            10,
        );
        open_total += open.best_speedup();
    }
    assert!(
        ours_total > open_total * 0.95,
        "ours {ours_total:.2} vs openevolve {open_total:.2}"
    );
}

/// The distributed pool and the inline pipeline agree on outcomes.
#[test]
fn dist_pool_matches_inline_outcomes() {
    let task = catalog::find_task("1_Conv2D_ReLU_BiasAdd").unwrap();
    let genomes: Vec<_> = (0..12)
        .map(|i| {
            let mut g = kernelfoundry::ir::KernelGenome::direct_translation(&task.id);
            g.id = i;
            g.mem = kernelfoundry::ir::MemoryPattern::from_level((i % 4) as usize);
            g.params.slm_pad = true;
            g
        })
        .collect();
    let pool = WorkerPool::new(ClusterConfig::default());
    let records = pool.evaluate_batch(&task, genomes.clone());
    // Outcome class depends only on the genome (determinism of the
    // compile/correctness stages), so pool and inline agree.
    let mut inline = kernelfoundry::eval::EvalPipeline::new(
        task.clone(),
        ExecBackend::HwSim(DeviceProfile::b580()),
        ClusterConfig::default().seed,
    );
    for (g, r) in genomes.iter().zip(records.iter()) {
        let i = inline.evaluate(g);
        assert_eq!(i.outcome, r.outcome, "genome {}", g.id);
    }
}

/// Engine → database → report round trip.
#[test]
fn database_records_full_run() {
    let task = catalog::find_task("59_Matmul_Swish_Scaling").unwrap();
    let mut engine = EvolutionEngine::new(
        quick_config(),
        task,
        ExecBackend::HwSim(DeviceProfile::b580()),
    );
    let report = engine.run(false);
    let db = Database::new();
    for (i, rec) in engine.records.values().enumerate() {
        db.insert(DbRow::from_record("it", "kernelfoundry", i, rec));
    }
    assert_eq!(db.len(), report.evaluations);
    let best = db.best_per_task("kernelfoundry");
    assert_eq!(best.len(), 1);
    assert!((best[0].speedup - report.best_speedup()).abs() < 1e-9);
}

/// YAML config drives the engine end to end (App. C config layer).
#[test]
fn yaml_config_controls_run() {
    let yaml = "\
evolution:
  max_generations: 6
  population: 3
  selection: uniform
llm:
  models: [sonnet-4.5]
device: lnl
";
    let config = FoundryConfig::from_yaml(yaml).unwrap();
    let task = catalog::find_task("20_LeakyReLU").unwrap();
    let device = DeviceProfile::by_name(&config.device).unwrap();
    let mut engine = EvolutionEngine::new(config, task, ExecBackend::HwSim(device));
    let report = engine.run(false);
    assert_eq!(report.series.len(), 6);
    assert_eq!(report.evaluations, 18);
}

/// App. D task filtering: strict criteria exclude all compromised tasks,
/// relaxed criteria keep criterion-(3)/(5) tasks.
#[test]
fn task_filtering_appendix_d() {
    let mut all = catalog::representative_set();
    all.extend(catalog::compromised_examples());
    let strict: Vec<_> = all.iter().filter(|t| !t.flags.compromised_strict()).collect();
    let relaxed: Vec<_> = all.iter().filter(|t| !t.flags.compromised_relaxed()).collect();
    assert_eq!(strict.len(), 40);
    assert_eq!(relaxed.len(), 42); // comp_axis_std & comp_slow_baseline retained
    assert!(relaxed.len() > strict.len());
}

/// Real-backend integration (requires `make artifacts`; skips otherwise).
#[test]
fn real_backend_evolution_llama_rope() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let backend = PjrtBackend::new(manifest).unwrap();
    let task = catalog::llama_rope_task();
    let mut config = quick_config();
    config.evolution.max_generations = 4;
    config.evolution.population = 3;
    let mut engine = EvolutionEngine::new(config, task, ExecBackend::Real(Box::new(backend)));
    let report = engine.run(false);
    let best = report.best.expect("correct kernel on the real backend");
    assert!(best.time_ms > 0.0);
    assert!(best.correctness.unwrap().correct);
}
