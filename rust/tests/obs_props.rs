//! Property tests for the observability subsystem (ISSUE 7 satellite):
//! histogram bucket-count conservation and order-independent snapshot
//! merging, over randomized observation streams.

use kernelfoundry::obs::{bucket_bounds, Histogram, Registry, Snapshot, HIST_BUCKETS};
use kernelfoundry::util::prop::{check, F64In, VecOf};

/// Observation values spanning every bucket: negatives (clamped to 0),
/// sub-microsecond, mid-range, and far past the largest finite bound.
fn obs_gen() -> VecOf<F64In> {
    VecOf(F64In(-5.0, 500_000.0), 64)
}

#[test]
fn bucket_counts_always_sum_to_observation_count() {
    check(0x0b5_1, &obs_gen(), |values| {
        let h = Histogram::default();
        for v in values {
            h.observe(*v);
        }
        let s = h.snapshot();
        s.count() == values.len() as u64 && s.buckets.iter().sum::<u64>() == values.len() as u64
    });
}

#[test]
fn bucket_counts_conserved_under_extreme_values() {
    // Non-finite and extreme inputs still land in exactly one bucket.
    let h = Histogram::default();
    for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0, 0.0, 1e300] {
        h.observe(v);
    }
    let s = h.snapshot();
    assert_eq!(s.count(), 6);
    assert_eq!(s.buckets.len(), HIST_BUCKETS + 1);
    assert_eq!(s.buckets.iter().sum::<u64>(), 6);
}

#[test]
fn merged_snapshots_are_order_independent() {
    check(0x0b5_2, &obs_gen(), |values| {
        // Split the stream across three registries, as three daemons (or
        // the per-service + global registries) would record it.
        let parts: Vec<Snapshot> = values
            .chunks(values.len() / 3 + 1)
            .map(|chunk| {
                let r = Registry::new();
                for (i, v) in chunk.iter().enumerate() {
                    r.observe_ms("kf_stage_run_ms", *v);
                    r.counter("kf_units_committed_total").add(1 + (i as u64 % 3));
                    r.gauge("kf_queue_depth").set(*v);
                }
                r.snapshot()
            })
            .collect();

        let merge_in = |order: &[usize]| {
            let mut acc = Snapshot::default();
            for &i in order {
                if i < parts.len() {
                    acc.merge(&parts[i]);
                }
            }
            acc
        };
        let fwd = merge_in(&[0, 1, 2]);
        let rev = merge_in(&[2, 1, 0]);
        let rot = merge_in(&[1, 2, 0]);
        if fwd != rev || fwd != rot {
            return false;
        }
        // The merge conserves observations and renders identically.
        let total: u64 = fwd
            .histograms
            .get("kf_stage_run_ms")
            .map(|h| h.count())
            .unwrap_or(0);
        total == values.len() as u64 && fwd.to_prometheus() == rev.to_prometheus()
    });
}

#[test]
fn quantiles_track_the_bucket_bounds() {
    check(0x0b5_3, &F64In(0.0, 100_000.0), |v| {
        let h = Histogram::default();
        h.observe(*v);
        let s = h.snapshot();
        let q = s.quantile(0.5);
        // The quantile is a bucket upper bound at or above the clamped
        // observation (or the largest finite bound for overflow values).
        let bounds = bucket_bounds();
        let last = bounds[bounds.len() - 1];
        bounds.contains(&q) && (q >= v.min(last) || (q - last).abs() < 1e-12)
    });
}
