//! Property tests for the observability subsystem: histogram
//! bucket-count conservation, order-independent snapshot merging,
//! rolling-window delta invariants (`obs::window`) and the debounced
//! alert state machine (`obs::alerts`), over randomized streams.

use kernelfoundry::obs::window::{histogram_delta, WindowDelta, WindowedQuantiles};
use kernelfoundry::obs::{
    bucket_bounds, AlertEngine, Histogram, Registry, RuleSet, Snapshot, HIST_BUCKETS,
};
use kernelfoundry::util::prop::{check, F64In, PairOf, UsizeIn, VecOf};

/// Observation values spanning every bucket: negatives (clamped to 0),
/// sub-microsecond, mid-range, and far past the largest finite bound.
fn obs_gen() -> VecOf<F64In> {
    VecOf(F64In(-5.0, 500_000.0), 64)
}

#[test]
fn bucket_counts_always_sum_to_observation_count() {
    check(0x0b5_1, &obs_gen(), |values| {
        let h = Histogram::default();
        for v in values {
            h.observe(*v);
        }
        let s = h.snapshot();
        s.count() == values.len() as u64 && s.buckets.iter().sum::<u64>() == values.len() as u64
    });
}

#[test]
fn bucket_counts_conserved_under_extreme_values() {
    // Non-finite and extreme inputs still land in exactly one bucket.
    let h = Histogram::default();
    for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0, 0.0, 1e300] {
        h.observe(v);
    }
    let s = h.snapshot();
    assert_eq!(s.count(), 6);
    assert_eq!(s.buckets.len(), HIST_BUCKETS + 1);
    assert_eq!(s.buckets.iter().sum::<u64>(), 6);
}

#[test]
fn merged_snapshots_are_order_independent() {
    check(0x0b5_2, &obs_gen(), |values| {
        // Split the stream across three registries, as three daemons (or
        // the per-service + global registries) would record it.
        let parts: Vec<Snapshot> = values
            .chunks(values.len() / 3 + 1)
            .map(|chunk| {
                let r = Registry::new();
                for (i, v) in chunk.iter().enumerate() {
                    r.observe_ms("kf_stage_run_ms", *v);
                    r.counter("kf_units_committed_total").add(1 + (i as u64 % 3));
                    r.gauge("kf_queue_depth").set(*v);
                }
                r.snapshot()
            })
            .collect();

        let merge_in = |order: &[usize]| {
            let mut acc = Snapshot::default();
            for &i in order {
                if i < parts.len() {
                    acc.merge(&parts[i]);
                }
            }
            acc
        };
        let fwd = merge_in(&[0, 1, 2]);
        let rev = merge_in(&[2, 1, 0]);
        let rot = merge_in(&[1, 2, 0]);
        if fwd != rev || fwd != rot {
            return false;
        }
        // The merge conserves observations and renders identically.
        let total: u64 = fwd
            .histograms
            .get("kf_stage_run_ms")
            .map(|h| h.count())
            .unwrap_or(0);
        total == values.len() as u64 && fwd.to_prometheus() == rev.to_prometheus()
    });
}

#[test]
fn quantiles_track_the_bucket_bounds() {
    check(0x0b5_3, &F64In(0.0, 100_000.0), |v| {
        let h = Histogram::default();
        h.observe(*v);
        let s = h.snapshot();
        let q = s.quantile(0.5);
        // The quantile is a bucket upper bound at or above the clamped
        // observation (or the largest finite bound for overflow values).
        let bounds = bucket_bounds();
        let last = bounds[bounds.len() - 1];
        bounds.contains(&q) && (q >= v.min(last) || (q - last).abs() < 1e-12)
    });
}

#[test]
fn windowed_quantiles_stay_inside_the_cumulative_envelope() {
    check(0x0b5_4, &PairOf(obs_gen(), obs_gen()), |(first, second)| {
        let h = Histogram::default();
        for v in first {
            h.observe(*v);
        }
        let prev = h.snapshot();
        for v in second {
            h.observe(*v);
        }
        let next = h.snapshot();
        let wq = WindowedQuantiles::of(&histogram_delta(&prev, &next));
        if wq.count != second.len() as u64 || wq.p50 > wq.p90 || wq.p90 > wq.p99 {
            return false;
        }
        // The window's buckets are a subset of the cumulative ones, so
        // every windowed quantile is bounded by the cumulative maximum.
        second.is_empty() || wq.p99 <= next.quantile(1.0)
    });
}

#[test]
fn window_rates_are_nonnegative_and_merge_order_independent() {
    check(0x0b5_5, &obs_gen(), |values| {
        // Three registries, as three daemons (or the per-service and
        // global registries) would record the same stream.
        let parts: Vec<Snapshot> = values
            .chunks(values.len() / 3 + 1)
            .map(|chunk| {
                let r = Registry::new();
                for v in chunk {
                    r.counter("kf_jobs_submitted_total").add(v.abs() as u64 % 5 + 1);
                    r.observe_ms("kf_stage_queued_ms", *v);
                }
                r.snapshot()
            })
            .collect();
        let merge_in = |order: &[usize]| {
            let mut acc = Snapshot::default();
            for &i in order {
                if i < parts.len() {
                    acc.merge(&parts[i]);
                }
            }
            acc
        };
        // prev = the first part alone; next = everything, merged in two
        // different orders. The window must not care about the order.
        let prev = merge_in(&[0]);
        let fwd = WindowDelta::between(&prev, &merge_in(&[0, 1, 2]), 0.0, 2_000.0);
        let rev = WindowDelta::between(&prev, &merge_in(&[2, 1, 0]), 0.0, 2_000.0);
        let sane = fwd.rates.values().all(|r| *r >= 0.0 && r.is_finite())
            && fwd.counter_deltas.values().all(|d| *d > 0);
        fwd == rev && sane
    });
}

#[test]
fn alert_edges_alternate_and_respect_the_debounce() {
    // Random breach/heal sequences at a 100 ms tick against a rule that
    // needs a 250 ms sustained breach. Firing may only appear after the
    // breach has been held for the full debounce window; `firing` and
    // `resolved` strictly alternate starting with `firing`; `resolved`
    // only ever lands on a healthy tick.
    check(0x0b5_6, &VecOf(UsizeIn(0, 1), 64), |bits| {
        let set = RuleSet::parse("r: m < 10 for 250ms").unwrap();
        let mut engine = AlertEngine::new(set);
        let step = 100.0;
        let mut states = Vec::new();
        let mut run = 0usize;
        for (i, bit) in bits.iter().enumerate() {
            let breach = *bit == 1;
            run = if breach { run + 1 } else { 0 };
            let value = if breach { 50.0 } else { 0.0 };
            for t in engine.eval(|_| Some(value), i as f64 * step) {
                match t.state.as_str() {
                    "firing" if (run.max(1) - 1) as f64 * step < 250.0 => return false,
                    "resolved" if breach => return false,
                    _ => {}
                }
                states.push(t.state);
            }
        }
        states
            .iter()
            .enumerate()
            .all(|(k, s)| s == if k % 2 == 0 { "firing" } else { "resolved" })
    });
}
