//! Property-based tests over coordinator invariants (archive semantics,
//! selection, gradients, fitness, queue state), using the in-repo
//! property-testing substrate (`util::prop`, the proptest replacement).

use kernelfoundry::archive::{Elite, MapElites};
use kernelfoundry::classify::{cell_index, coords_of};
use kernelfoundry::eval::fitness::fitness;
use kernelfoundry::gradient::GradientEstimator;
use kernelfoundry::ir::KernelGenome;
use kernelfoundry::metrics;
use kernelfoundry::selection::{Selector, Strategy};
use kernelfoundry::transitions::{Outcome, Transition, TransitionTracker};
use kernelfoundry::util::prop::{check_cases, F64In, Gen, PairOf, UsizeIn, VecOf};
use kernelfoundry::util::rng::Rng;

fn elite(coords: [usize; 3], f: f64) -> Elite {
    Elite {
        genome: KernelGenome::direct_translation("p"),
        coords,
        fitness: f,
        speedup: f,
        runtime_ms: 1.0,
        iteration: 0,
    }
}

/// Generator of random insertion sequences: (cell index, fitness).
struct Insertions;
impl Gen for Insertions {
    type Value = Vec<(usize, f64)>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(120);
        (0..n).map(|_| (rng.below(64), rng.f64())).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        if v.is_empty() {
            vec![]
        } else {
            vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
        }
    }
}

/// Archive invariant: each occupied cell holds exactly the maximum
/// fitness ever inserted into it; unoccupied cells received nothing.
#[test]
fn prop_archive_keeps_per_cell_maximum() {
    check_cases(11, 200, &Insertions, |seq| {
        let mut archive = MapElites::new(4);
        let mut best: std::collections::HashMap<usize, f64> = Default::default();
        for (cell, f) in seq {
            archive.insert(elite(coords_of(*cell, 4), *f));
            let e = best.entry(*cell).or_insert(f64::MIN);
            if *f > *e {
                *e = *f;
            }
        }
        for idx in 0..64 {
            let got = archive.get(coords_of(idx, 4)).map(|e| e.fitness);
            match (got, best.get(&idx)) {
                (None, None) => {}
                (Some(g), Some(b)) => {
                    if (g - b).abs() > 1e-12 {
                        return false;
                    }
                }
                _ => return false,
            }
        }
        // QD score equals the sum of per-cell maxima.
        let qd: f64 = best.values().sum();
        (archive.qd_score() - qd).abs() < 1e-9
    });
}

/// Selection invariant: every strategy returns an occupied cell, for any
/// archive occupancy pattern.
#[test]
fn prop_selection_returns_occupied() {
    check_cases(12, 100, &Insertions, |seq| {
        let mut archive = MapElites::new(4);
        for (cell, f) in seq {
            archive.insert(elite(coords_of(*cell, 4), *f));
        }
        let tracker = TransitionTracker::new(16);
        let mut rng = Rng::new(99);
        for strat in [
            Strategy::Uniform,
            Strategy::FitnessProportionate,
            Strategy::Curiosity,
            Strategy::Island,
        ] {
            let sel = Selector::new(strat);
            for it in 0..4 {
                match sel.select(&archive, &tracker, it, &mut rng) {
                    Some(c) => {
                        if archive.get(c).is_none() {
                            return false;
                        }
                    }
                    None => {
                        if archive.n_occupied() != 0 {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

/// Transition generator for gradient properties.
struct Transitions;
impl Gen for Transitions {
    type Value = Vec<Transition>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(80);
        (0..n)
            .map(|i| {
                let pf = rng.f64();
                let cf = rng.f64();
                Transition {
                    parent_coords: [rng.below(4), rng.below(4), rng.below(4)],
                    child_coords: [rng.below(4), rng.below(4), rng.below(4)],
                    parent_fitness: pf,
                    child_fitness: cf,
                    outcome: if cf > pf {
                        Outcome::Improvement
                    } else {
                        Outcome::Regression
                    },
                    iteration: i,
                }
            })
            .collect()
    }
}

/// Gradient bounds: ∇R components are probability differences in
/// [-1, 1]; all estimates are finite for any history.
#[test]
fn prop_gradient_bounds() {
    check_cases(13, 150, &Transitions, |ts| {
        let mut tracker = TransitionTracker::new(64);
        for t in ts {
            tracker.record(*t);
        }
        let mut archive = MapElites::new(4);
        archive.insert(elite([0, 0, 0], 0.5));
        let est = GradientEstimator::default();
        let g = est.estimate(&tracker, &archive, [0, 0, 0], ts.len());
        g.improvement.d.iter().all(|x| (-1.0..=1.0).contains(x))
            && g.combined.d.iter().all(|x| x.is_finite())
            && g.exploration.magnitude() <= 1.0 + 1e-9
    });
}

/// Fitness function bounds and the correctness-dominance ordering
/// (§3.2): any correct kernel outscores any incorrect/failed one.
#[test]
fn prop_fitness_bounds_and_dominance() {
    let gen = PairOf(F64In(0.0, 60.0), F64In(0.5, 8.0));
    check_cases(14, 300, &gen, |(speedup, target)| {
        let f_ok = fitness(true, true, *speedup, *target);
        let f_bad = fitness(true, false, *speedup, *target);
        let f_cc = fitness(false, false, *speedup, *target);
        (0.5..=1.0).contains(&f_ok) && f_bad == 0.1 && f_cc == 0.0 && f_ok > f_bad && f_bad > f_cc
    });
}

/// fast_p is monotone non-increasing in p and bounded by correct-rate.
#[test]
fn prop_fastp_monotone() {
    let gen = VecOf(F64In(0.0, 5.0), 40);
    check_cases(15, 200, &gen, |speeds| {
        let results: Vec<metrics::TaskResult> = speeds
            .iter()
            .enumerate()
            .map(|(i, s)| metrics::TaskResult {
                task_id: format!("t{i}"),
                correct: *s > 0.0,
                speedup: *s,
                time_ms: 1.0,
            })
            .collect();
        let agg = metrics::aggregate(&results);
        let f05 = metrics::fast_p(&results, 0.5);
        let f1 = metrics::fast_p(&results, 1.0);
        let f2 = metrics::fast_p(&results, 2.0);
        f05 >= f1 && f1 >= f2 && f05 <= agg.correct_rate + 1e-12
    });
}

/// Cell index bijection over arbitrary bins.
#[test]
fn prop_cell_index_bijection() {
    let gen = PairOf(UsizeIn(2, 8), UsizeIn(0, 511));
    check_cases(16, 200, &gen, |(bins, raw)| {
        let idx = raw % (bins * bins * bins);
        cell_index(coords_of(idx, *bins), *bins) == idx
    });
}

/// End-to-end state invariant: random evolution runs never violate
/// record accounting (evaluations = compile_errors + incorrect +
/// correct-evals; series is monotone; archive never exceeds 64 cells).
#[test]
fn prop_engine_accounting() {
    use kernelfoundry::config::FoundryConfig;
    use kernelfoundry::coordinator::EvolutionEngine;
    use kernelfoundry::eval::ExecBackend;
    use kernelfoundry::hwsim::DeviceProfile;
    use kernelfoundry::tasks::catalog;

    let gen = PairOf(UsizeIn(0, 1000), UsizeIn(2, 5));
    check_cases(17, 12, &gen, |(seed, pop)| {
        let mut config = FoundryConfig::paper_defaults();
        config.seed = *seed as u64;
        config.evolution.max_generations = 6;
        config.evolution.population = *pop;
        let task = catalog::find_task("46_Conv2d_Subtract_Tanh_Subtract_AvgPool").unwrap();
        let mut engine =
            EvolutionEngine::new(config, task, ExecBackend::HwSim(DeviceProfile::b580()));
        let report = engine.run(false);
        let monotone = report
            .series
            .windows(2)
            .all(|w| w[1].best_speedup >= w[0].best_speedup - 1e-12);
        let correct_evals = report.evaluations - report.compile_errors - report.incorrect;
        report.evaluations == 6 * pop
            && monotone
            && correct_evals <= report.evaluations
            && report.archive.map(|a| a.occupied <= 64).unwrap_or(false)
    });
}
