//! Property-based tests over coordinator invariants (archive semantics,
//! selection, gradients, fitness, queue state), using the in-repo
//! property-testing substrate (`util::prop`, the proptest replacement).

use kernelfoundry::archive::{Elite, MapElites};
use kernelfoundry::classify::{cell_index, coords_of};
use kernelfoundry::dist::{Database, DbRow};
use kernelfoundry::eval::fitness::fitness;
use kernelfoundry::gradient::GradientEstimator;
use kernelfoundry::ir::KernelGenome;
use kernelfoundry::metrics;
use kernelfoundry::selection::{Selector, Strategy};
use kernelfoundry::service::cache::cache_key;
use kernelfoundry::service::journal::{replay, Journal, JournalRecord, SubmitUnit};
use kernelfoundry::service::{DeviceResult, JobSpec};
use kernelfoundry::transitions::{Outcome, Transition, TransitionTracker};
use kernelfoundry::util::prop::{check_cases, F64In, Gen, PairOf, UsizeIn, VecOf};
use kernelfoundry::util::rng::Rng;

fn elite(coords: [usize; 3], f: f64) -> Elite {
    Elite {
        genome: KernelGenome::direct_translation("p"),
        coords,
        fitness: f,
        speedup: f,
        runtime_ms: 1.0,
        iteration: 0,
    }
}

/// Generator of random insertion sequences: (cell index, fitness).
struct Insertions;
impl Gen for Insertions {
    type Value = Vec<(usize, f64)>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(120);
        (0..n).map(|_| (rng.below(64), rng.f64())).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        if v.is_empty() {
            vec![]
        } else {
            vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
        }
    }
}

/// Archive invariant: each occupied cell holds exactly the maximum
/// fitness ever inserted into it; unoccupied cells received nothing.
#[test]
fn prop_archive_keeps_per_cell_maximum() {
    check_cases(11, 200, &Insertions, |seq| {
        let mut archive = MapElites::new(4);
        let mut best: std::collections::HashMap<usize, f64> = Default::default();
        for (cell, f) in seq {
            archive.insert(elite(coords_of(*cell, 4), *f));
            let e = best.entry(*cell).or_insert(f64::MIN);
            if *f > *e {
                *e = *f;
            }
        }
        for idx in 0..64 {
            let got = archive.get(coords_of(idx, 4)).map(|e| e.fitness);
            match (got, best.get(&idx)) {
                (None, None) => {}
                (Some(g), Some(b)) => {
                    if (g - b).abs() > 1e-12 {
                        return false;
                    }
                }
                _ => return false,
            }
        }
        // QD score equals the sum of per-cell maxima.
        let qd: f64 = best.values().sum();
        (archive.qd_score() - qd).abs() < 1e-9
    });
}

/// Selection invariant: every strategy returns an occupied cell, for any
/// archive occupancy pattern.
#[test]
fn prop_selection_returns_occupied() {
    check_cases(12, 100, &Insertions, |seq| {
        let mut archive = MapElites::new(4);
        for (cell, f) in seq {
            archive.insert(elite(coords_of(*cell, 4), *f));
        }
        let tracker = TransitionTracker::new(16);
        let mut rng = Rng::new(99);
        for strat in [
            Strategy::Uniform,
            Strategy::FitnessProportionate,
            Strategy::Curiosity,
            Strategy::Island,
        ] {
            let sel = Selector::new(strat);
            for it in 0..4 {
                match sel.select(&archive, &tracker, it, &mut rng) {
                    Some(c) => {
                        if archive.get(c).is_none() {
                            return false;
                        }
                    }
                    None => {
                        if archive.n_occupied() != 0 {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

/// Transition generator for gradient properties.
struct Transitions;
impl Gen for Transitions {
    type Value = Vec<Transition>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(80);
        (0..n)
            .map(|i| {
                let pf = rng.f64();
                let cf = rng.f64();
                Transition {
                    parent_coords: [rng.below(4), rng.below(4), rng.below(4)],
                    child_coords: [rng.below(4), rng.below(4), rng.below(4)],
                    parent_fitness: pf,
                    child_fitness: cf,
                    outcome: if cf > pf {
                        Outcome::Improvement
                    } else {
                        Outcome::Regression
                    },
                    iteration: i,
                }
            })
            .collect()
    }
}

/// Gradient bounds: ∇R components are probability differences in
/// [-1, 1]; all estimates are finite for any history.
#[test]
fn prop_gradient_bounds() {
    check_cases(13, 150, &Transitions, |ts| {
        let mut tracker = TransitionTracker::new(64);
        for t in ts {
            tracker.record(*t);
        }
        let mut archive = MapElites::new(4);
        archive.insert(elite([0, 0, 0], 0.5));
        let est = GradientEstimator::default();
        let g = est.estimate(&tracker, &archive, [0, 0, 0], ts.len());
        g.improvement.d.iter().all(|x| (-1.0..=1.0).contains(x))
            && g.combined.d.iter().all(|x| x.is_finite())
            && g.exploration.magnitude() <= 1.0 + 1e-9
    });
}

/// Fitness function bounds and the correctness-dominance ordering
/// (§3.2): any correct kernel outscores any incorrect/failed one.
#[test]
fn prop_fitness_bounds_and_dominance() {
    let gen = PairOf(F64In(0.0, 60.0), F64In(0.5, 8.0));
    check_cases(14, 300, &gen, |(speedup, target)| {
        let f_ok = fitness(true, true, *speedup, *target);
        let f_bad = fitness(true, false, *speedup, *target);
        let f_cc = fitness(false, false, *speedup, *target);
        (0.5..=1.0).contains(&f_ok) && f_bad == 0.1 && f_cc == 0.0 && f_ok > f_bad && f_bad > f_cc
    });
}

/// fast_p is monotone non-increasing in p and bounded by correct-rate.
#[test]
fn prop_fastp_monotone() {
    let gen = VecOf(F64In(0.0, 5.0), 40);
    check_cases(15, 200, &gen, |speeds| {
        let results: Vec<metrics::TaskResult> = speeds
            .iter()
            .enumerate()
            .map(|(i, s)| metrics::TaskResult {
                task_id: format!("t{i}"),
                correct: *s > 0.0,
                speedup: *s,
                time_ms: 1.0,
            })
            .collect();
        let agg = metrics::aggregate(&results);
        let f05 = metrics::fast_p(&results, 0.5);
        let f1 = metrics::fast_p(&results, 1.0);
        let f2 = metrics::fast_p(&results, 2.0);
        f05 >= f1 && f1 >= f2 && f05 <= agg.correct_rate + 1e-12
    });
}

/// Cell index bijection over arbitrary bins.
#[test]
fn prop_cell_index_bijection() {
    let gen = PairOf(UsizeIn(2, 8), UsizeIn(0, 511));
    check_cases(16, 200, &gen, |(bins, raw)| {
        let idx = raw % (bins * bins * bins);
        cell_index(coords_of(idx, *bins), *bins) == idx
    });
}

fn fake_result(device: &str, id: u64) -> DeviceResult {
    DeviceResult {
        device: device.to_string(),
        task_id: "20_LeakyReLU".to_string(),
        correct: true,
        fitness: 0.9,
        speedup: 1.5,
        time_ms: 0.5,
        baseline_ms: 0.75,
        coords: [1, 2, 3],
        genome_id: id,
        produced_by: "sim".to_string(),
        source: String::new(),
        evaluations: 6,
        compile_errors: 1,
        incorrect: 2,
        cached: false,
        wall_ms: 3.0,
    }
}

/// Generator of random journal logs: each job is left at a random
/// lifecycle stage (submitted / dispatched / committed / failed /
/// cancelled / cached / mid-retry / quarantined / rerouted) on a
/// random device.
struct JournalLogs;
impl Gen for JournalLogs {
    type Value = Vec<JournalRecord>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n_jobs = rng.below(8);
        let mut recs = vec![JournalRecord::Lease {
            owner: "kf-prop".to_string(),
            ts_ms: 1.0,
        }];
        for j in 0..n_jobs {
            let job_id = j as u64 + 1;
            let device = if rng.below(2) == 0 { "b580" } else { "lnl" };
            let other = if device == "b580" { "lnl" } else { "b580" };
            let mut spec = JobSpec::catalog("20_LeakyReLU", device);
            spec.seed = job_id;
            let stage = rng.below(9);
            recs.push(JournalRecord::Submit {
                job_id,
                spec,
                units: vec![SubmitUnit {
                    device: device.to_string(),
                    cached: stage == 5,
                }],
            });
            if (1..5).contains(&stage) || stage == 6 || stage == 7 {
                recs.push(JournalRecord::Dispatch {
                    job_id,
                    device: device.to_string(),
                });
            }
            match stage {
                2 => recs.push(JournalRecord::Commit {
                    job_id,
                    device: device.to_string(),
                    result: fake_result(device, job_id),
                }),
                3 => recs.push(JournalRecord::Fail {
                    job_id,
                    device: device.to_string(),
                    error: "boom".to_string(),
                }),
                4 => recs.push(JournalRecord::Cancel {
                    job_id,
                    devices: vec![device.to_string()],
                }),
                // Crashed mid-retry: the unit replays as queued with its
                // attempt budget intact.
                6 => recs.push(JournalRecord::Retry {
                    job_id,
                    device: device.to_string(),
                    attempt: 1,
                    error: "transient".to_string(),
                }),
                // Retried once, then quarantined: a terminal verdict.
                7 => {
                    recs.push(JournalRecord::Retry {
                        job_id,
                        device: device.to_string(),
                        attempt: 1,
                        error: "transient".to_string(),
                    });
                    recs.push(JournalRecord::Dispatch {
                        job_id,
                        device: device.to_string(),
                    });
                    recs.push(JournalRecord::Quarantine {
                        job_id,
                        device: device.to_string(),
                        error: "transient".to_string(),
                        attempts: 2,
                    });
                }
                // Rerouted off a tripped lane, then finished elsewhere.
                8 => {
                    recs.push(JournalRecord::Reroute {
                        job_id,
                        from: device.to_string(),
                        to: other.to_string(),
                    });
                    recs.push(JournalRecord::Dispatch {
                        job_id,
                        device: other.to_string(),
                    });
                    recs.push(JournalRecord::Commit {
                        job_id,
                        device: other.to_string(),
                        result: fake_result(other, job_id),
                    });
                }
                _ => {}
            }
        }
        recs
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        if v.len() <= 1 {
            vec![]
        } else {
            vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
        }
    }
}

/// Journal replay is an idempotent fold: replaying a log twice over —
/// the state a crashed daemon leaves if it dies right after a restart
/// that re-journals nothing — lands on exactly the same state, and the
/// id high-water mark is stable.
#[test]
fn prop_journal_replay_idempotent() {
    check_cases(21, 150, &JournalLogs, |recs| {
        let once = replay(recs);
        let mut doubled = recs.clone();
        doubled.extend(recs.iter().cloned());
        let twice = replay(&doubled);
        once == twice && once.max_job_id() == twice.max_job_id()
    });
}

/// Generator of crash cuts for the slot-commit protocol: n slots, a
/// crash after a random prefix of the (marker, row) op sequence, plus a
/// random torn-tail length for the interrupted append.
struct CrashCut;
impl Gen for CrashCut {
    type Value = (usize, usize, usize);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = 1 + rng.below(4);
        (n, rng.below(2 * n + 1), 1 + rng.below(24))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.1 > 0 {
            out.push((v.0, v.1 - 1, v.2));
        }
        if v.0 > 1 {
            out.push((v.0 - 1, v.1.min(2 * (v.0 - 1)), v.2));
        }
        out
    }
}

/// Slot-commit safety over real files: whatever op the crash interrupts
/// (and whatever torn bytes it leaves), after tolerant reload every
/// result row in the db has a matching commit marker in the journal —
/// markers strictly lead rows, so a row without provenance is
/// impossible.
#[test]
fn prop_no_result_row_without_commit_marker() {
    let dir = std::env::temp_dir();
    let journal_path = dir.join(format!("kf_prop_cut_{}.journal.jsonl", std::process::id()));
    let db_path = dir.join(format!("kf_prop_cut_{}.db.jsonl", std::process::id()));
    check_cases(22, 120, &CrashCut, |&(n, crash_op, torn)| {
        let spec_for = |k: usize| {
            let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
            spec.seed = k as u64;
            spec
        };
        let row_for = |k: usize| DbRow {
            run: cache_key(&spec_for(k), "b580"),
            method: "service".to_string(),
            idx: k,
            task_id: "20_LeakyReLU".to_string(),
            genome_id: k as u64,
            produced_by: "sim".to_string(),
            outcome: "correct".to_string(),
            coords: [1, 2, 3],
            fitness: 0.9,
            speedup: 1.5,
            time_ms: 0.5,
            baseline_ms: 0.75,
        };
        let marker_line = |k: usize| {
            JournalRecord::Commit {
                job_id: k as u64,
                device: "b580".to_string(),
                result: fake_result("b580", k as u64),
            }
            .to_json()
            .to_string_compact()
                + "\n"
        };
        let row_line = |k: usize| row_for(k).to_json().to_string_compact() + "\n";

        // Preamble: lease + every submit/dispatch, then the interleaved
        // (marker_k, row_k) op sequence cut at `crash_op`, with a torn
        // prefix of the interrupted line left behind.
        let mut journal = JournalRecord::Lease {
            owner: "kf-prop".to_string(),
            ts_ms: 1.0,
        }
        .to_json()
        .to_string_compact()
            + "\n";
        for k in 1..=n {
            journal += &(JournalRecord::Submit {
                job_id: k as u64,
                spec: spec_for(k),
                units: vec![SubmitUnit {
                    device: "b580".to_string(),
                    cached: false,
                }],
            }
            .to_json()
            .to_string_compact()
                + "\n");
            journal += &(JournalRecord::Dispatch {
                job_id: k as u64,
                device: "b580".to_string(),
            }
            .to_json()
            .to_string_compact()
                + "\n");
        }
        let mut db = String::new();
        for op in 0..crash_op {
            let k = op / 2 + 1;
            if op % 2 == 0 {
                journal += &marker_line(k);
            } else {
                db += &row_line(k);
            }
        }
        if crash_op < 2 * n {
            let k = crash_op / 2 + 1;
            if crash_op % 2 == 0 {
                let line = marker_line(k);
                journal += &line[..torn.min(line.len() - 1)];
            } else {
                let line = row_line(k);
                db += &line[..torn.min(line.len() - 1)];
            }
        }
        std::fs::write(&journal_path, journal).unwrap();
        std::fs::write(&db_path, db).unwrap();

        let records = Journal::load_records(&journal_path).unwrap();
        let committed: std::collections::HashSet<String> = records
            .iter()
            .filter_map(|r| match r {
                JournalRecord::Commit { job_id, .. } => {
                    Some(cache_key(&spec_for(*job_id as usize), "b580"))
                }
                _ => None,
            })
            .collect();
        let database = Database::new();
        database.load_tolerant(&db_path).unwrap();
        database.rows().iter().all(|row| committed.contains(&row.run))
    });
    let _ = std::fs::remove_file(&journal_path);
    let _ = std::fs::remove_file(&db_path);
}

/// End-to-end state invariant: random evolution runs never violate
/// record accounting (evaluations = compile_errors + incorrect +
/// correct-evals; series is monotone; archive never exceeds 64 cells).
#[test]
fn prop_engine_accounting() {
    use kernelfoundry::config::FoundryConfig;
    use kernelfoundry::coordinator::EvolutionEngine;
    use kernelfoundry::eval::ExecBackend;
    use kernelfoundry::hwsim::DeviceProfile;
    use kernelfoundry::tasks::catalog;

    let gen = PairOf(UsizeIn(0, 1000), UsizeIn(2, 5));
    check_cases(17, 12, &gen, |(seed, pop)| {
        let mut config = FoundryConfig::paper_defaults();
        config.seed = *seed as u64;
        config.evolution.max_generations = 6;
        config.evolution.population = *pop;
        let task = catalog::find_task("46_Conv2d_Subtract_Tanh_Subtract_AvgPool").unwrap();
        let mut engine =
            EvolutionEngine::new(config, task, ExecBackend::HwSim(DeviceProfile::b580()));
        let report = engine.run(false);
        let monotone = report
            .series
            .windows(2)
            .all(|w| w[1].best_speedup >= w[0].best_speedup - 1e-12);
        let correct_evals = report.evaluations - report.compile_errors - report.incorrect;
        report.evaluations == 6 * pop
            && monotone
            && correct_evals <= report.evaluations
            && report.archive.map(|a| a.occupied <= 64).unwrap_or(false)
    });
}
