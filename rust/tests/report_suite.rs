//! Report-subsystem tests (ISSUE 8): property tests pinning the
//! analytics folds (order independence vs brute force, quantile
//! bounds), the `report regressions` exit-code contract, and the
//! observability end-to-end — a journaled + traced + search-logged
//! daemon whose artifacts feed `kernelfoundry report --html`.

use kernelfoundry::dist::DbRow;
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::obs::{stage, TraceEvent, TraceSink};
use kernelfoundry::report::history::{SearchLog, SearchStatsRow};
use kernelfoundry::report::views::{stage_deltas, LatencyView, SearchHealthView, TrajectoryView};
use kernelfoundry::service::{
    proto, Client, JobSpec, KernelService, Request, Server, ServiceConfig,
};
use kernelfoundry::util::json::Json;
use kernelfoundry::util::prop::{check_cases, Gen};
use kernelfoundry::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Property: trajectory fold is order-independent and matches brute force
// ---------------------------------------------------------------------------

/// Row spec: (task idx, run idx, coords idx, fitness, speedup, correct).
type RowSpec = (usize, usize, usize, f64, f64, bool);

const TASKS: [&str; 2] = ["20_LeakyReLU", "synthetic_other"];
const RUNS: [&str; 4] = [
    "cat:20_LeakyReLU|b580|sycl|s1|i3|p2",
    "cat:20_LeakyReLU|b580|sycl|s2|i3|p2",
    "cat:20_LeakyReLU|lnl|sycl|s1|i3|p2",
    "serve-run", // no `|`: device unknown, buckets under "-"
];
const COORDS: [[usize; 3]; 2] = [[0, 0, 0], [1, 2, 0]];

fn spec_row(spec: &RowSpec) -> DbRow {
    let (task, run, coords, fitness, speedup, correct) = *spec;
    DbRow {
        run: RUNS[run % RUNS.len()].to_string(),
        method: "service".to_string(),
        idx: 0,
        task_id: TASKS[task % TASKS.len()].to_string(),
        genome_id: 1,
        produced_by: "gpt-4.1".to_string(),
        outcome: if correct { "correct" } else { "compile_error" }.to_string(),
        coords: COORDS[coords % COORDS.len()],
        fitness,
        speedup,
        time_ms: 0.5,
        baseline_ms: 1.0,
    }
}

struct RowSpecs;
impl Gen for RowSpecs {
    type Value = Vec<RowSpec>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(60);
        (0..n)
            .map(|_| {
                (
                    rng.below(TASKS.len()),
                    rng.below(RUNS.len()),
                    rng.below(COORDS.len()),
                    rng.f64() * 2.0,
                    rng.f64() * 3.0,
                    rng.below(4) != 0, // mostly correct rows
                )
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        if v.is_empty() {
            vec![]
        } else {
            vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
        }
    }
}

fn device_of(run: &str) -> String {
    if run.contains('|') {
        run.split('|').nth(1).unwrap_or("-").to_string()
    } else {
        "-".to_string()
    }
}

#[test]
fn prop_trajectory_fold_is_order_independent_and_matches_brute_force() {
    check_cases(0x9e901, 150, &RowSpecs, |specs| {
        let rows: Vec<DbRow> = specs.iter().map(spec_row).collect();
        let view = TrajectoryView::build(&rows);

        // Order independence: any shuffle folds to the identical view.
        let mut shuffled = rows.clone();
        Rng::new(specs.len() as u64 + 7).shuffle(&mut shuffled);
        if TrajectoryView::build(&shuffled) != view {
            return false;
        }

        // Brute force: global lexicographic max of (fitness, speedup)
        // per (task, cell, device) over correct rows.
        let mut expect: BTreeMap<(String, [usize; 3], String), (f64, f64)> = BTreeMap::new();
        for row in rows.iter().filter(|r| r.is_correct()) {
            let key = (row.task_id.clone(), row.coords, device_of(&row.run));
            let e = expect.entry(key).or_insert((f64::NEG_INFINITY, 0.0));
            if row.fitness > e.0 || (row.fitness == e.0 && row.speedup > e.1) {
                *e = (row.fitness, row.speedup);
            }
        }
        if view.points.len() != expect.len() {
            return false;
        }
        view.points.iter().all(|p| {
            let key = (p.task_id.clone(), p.coords, p.device.clone());
            match expect.get(&key) {
                Some(&(f, s)) => {
                    (p.best_fitness - f).abs() < 1e-12 && (p.best_speedup - s).abs() < 1e-12
                }
                None => false,
            }
        })
    });
}

// ---------------------------------------------------------------------------
// Property: latency quantiles are bounded by the segment samples
// ---------------------------------------------------------------------------

/// Event spec: (stage idx, job id, device idx (0 = none), ts).
type EventSpec = (usize, u64, usize, f64);

const DEVICES: [&str; 2] = ["b580", "lnl"];

fn spec_event(spec: &EventSpec) -> TraceEvent {
    let (stage_idx, job, device, ts) = *spec;
    TraceEvent {
        stage: stage::ALL[stage_idx % stage::ALL.len()].to_string(),
        job_id: job,
        trace_id: format!("t{job}"),
        device: if device == 0 {
            None
        } else {
            Some(DEVICES[(device - 1) % DEVICES.len()].to_string())
        },
        ts_ms: ts,
    }
}

struct EventSpecs;
impl Gen for EventSpecs {
    type Value = Vec<EventSpec>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(80);
        (0..n)
            .map(|_| {
                (
                    rng.below(stage::ALL.len()),
                    rng.below(4) as u64,
                    rng.below(DEVICES.len() + 1),
                    rng.f64() * 1000.0,
                )
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        if v.is_empty() {
            vec![]
        } else {
            vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
        }
    }
}

#[test]
fn prop_latency_quantiles_bounded_by_segment_min_max() {
    check_cases(0x9e902, 150, &EventSpecs, |specs| {
        let events: Vec<TraceEvent> = specs.iter().map(spec_event).collect();
        let view = LatencyView::build(&events);
        let deltas = stage_deltas(&events);
        // Lanes and delta buckets are the same key set.
        if view.lanes.len() != deltas.len() {
            return false;
        }
        view.lanes.iter().all(|l| {
            let key = (l.device.clone(), l.segment.clone());
            let Some(samples) = deltas.get(&key) else {
                return false;
            };
            let lo = samples[0];
            let hi = samples[samples.len() - 1];
            l.n == samples.len()
                && l.min == lo
                && l.max == hi
                && lo <= l.p50
                && l.p50 <= l.p90
                && l.p90 <= l.p99
                && l.p99 <= hi
        })
    });
}

// ---------------------------------------------------------------------------
// Property: search-health fold is order-independent, curves per generation
// ---------------------------------------------------------------------------

/// Stats spec: (run idx, generation, qd, ts, attempts).
type StatsSpec = (usize, usize, f64, f64, usize);

fn spec_stats(spec: &StatsSpec) -> SearchStatsRow {
    let (run, generation, qd, ts, attempts) = *spec;
    SearchStatsRow {
        run: format!("run{run}"),
        task_id: "20_LeakyReLU".to_string(),
        device: "b580".to_string(),
        generation,
        qd_score: qd,
        coverage: 0.25,
        best_fitness: 0.5,
        best_speedup: 1.1,
        acceptance: 0.5,
        insertions: 1,
        attempts,
        occupied: 1,
        evaluations: 4,
        ts_ms: ts,
    }
}

struct StatsSpecs;
impl Gen for StatsSpecs {
    type Value = Vec<StatsSpec>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(50);
        (0..n)
            .map(|_| {
                (
                    rng.below(3),
                    rng.below(6),
                    rng.f64() * 10.0,
                    // Continuous timestamps: exact (ts, attempts) ties
                    // between distinct rows would make the dedup rule
                    // keep whichever arrived first.
                    rng.f64() * 1000.0,
                    rng.below(8),
                )
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        if v.is_empty() {
            vec![]
        } else {
            vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
        }
    }
}

#[test]
fn prop_search_health_is_order_independent_with_replay_dedup() {
    check_cases(0x9e903, 150, &StatsSpecs, |specs| {
        let rows: Vec<SearchStatsRow> = specs.iter().map(spec_stats).collect();
        let view = SearchHealthView::build(&rows);

        let mut shuffled = rows.clone();
        Rng::new(specs.len() as u64 + 13).shuffle(&mut shuffled);
        if SearchHealthView::build(&shuffled) != view {
            return false;
        }

        // Brute force: per run, per generation, the winning row is the
        // max-(ts, attempts) recording; curves walk generations in order.
        let mut expect: BTreeMap<String, BTreeMap<usize, (f64, usize, f64)>> = BTreeMap::new();
        for r in &rows {
            let gens = expect.entry(r.run.clone()).or_default();
            let cand = (r.ts_ms, r.attempts, r.qd_score);
            match gens.get(&r.generation) {
                Some(&(ts, att, _)) if (ts, att) >= (cand.0, cand.1) => {}
                _ => {
                    gens.insert(r.generation, cand);
                }
            }
        }
        if view.runs.len() != expect.len() {
            return false;
        }
        view.runs.iter().all(|run| match expect.get(&run.run) {
            Some(gens) => {
                let qd: Vec<f64> = gens.values().map(|&(_, _, q)| q).collect();
                run.generations() == gens.len() && run.qd_curve == qd
            }
            None => false,
        })
    });
}

// ---------------------------------------------------------------------------
// `report regressions` exit-code contract (drives the real binary)
// ---------------------------------------------------------------------------

fn synthetic_row(task: &str, speedup: f64) -> DbRow {
    DbRow {
        run: format!("cat:{task}|b580|sycl|s1|i3|p2"),
        method: "service".to_string(),
        idx: 0,
        task_id: task.to_string(),
        genome_id: 1,
        produced_by: "gpt-4.1".to_string(),
        outcome: "correct".to_string(),
        coords: [0, 0, 0],
        fitness: 1.0,
        speedup,
        time_ms: 0.5,
        baseline_ms: 1.0,
    }
}

fn write_db(path: &Path, rows: &[DbRow]) {
    let lines: String = rows
        .iter()
        .map(|r| format!("{}\n", r.to_json().to_string_compact()))
        .collect();
    std::fs::write(path, lines).expect("write synthetic db");
}

fn report_cmd(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_kernelfoundry"))
        .args(args)
        .output()
        .expect("spawn kernelfoundry")
}

#[test]
fn regressions_subcommand_gates_with_nonzero_exit() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let base = dir.join(format!("kf_report_base_{pid}.jsonl"));
    let cur = dir.join(format!("kf_report_cur_{pid}.jsonl"));
    write_db(&base, &[synthetic_row("a", 2.0), synthetic_row("b", 2.0)]);
    write_db(&cur, &[synthetic_row("a", 1.0), synthetic_row("b", 2.0)]);
    let (base_s, cur_s) = (base.to_str().unwrap(), cur.to_str().unwrap());

    // A 50% drop on task `a` beyond the 10% default tolerance: nonzero.
    let out = report_cmd(&["report", "regressions", "--db", cur_s, "--baseline", base_s]);
    assert!(!out.status.success(), "regressed db must exit nonzero");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("regression"), "stdout names the failure: {text}");
    assert!(text.contains("b580"), "regressed device listed: {text}");
    assert!(text.contains("-50.0%"), "drop percentage listed: {text}");

    // Machine-readable listing carries the same verdict.
    let out = report_cmd(&[
        "report", "regressions", "--db", cur_s, "--baseline", base_s, "--json",
    ]);
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("\"drop_frac\""), "{text}");

    // Widening the tolerance past the drop passes.
    let out = report_cmd(&[
        "report",
        "regressions",
        "--db",
        cur_s,
        "--baseline",
        base_s,
        "--max-speedup-drop",
        "0.6",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // A database compared against itself never regresses.
    let out = report_cmd(&["report", "regressions", "--db", base_s, "--baseline", base_s]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("no regressions"));

    // Missing --baseline is a usage error, not a silent pass.
    let out = report_cmd(&["report", "regressions", "--db", cur_s]);
    assert!(!out.status.success());

    let _ = std::fs::remove_file(&base);
    let _ = std::fs::remove_file(&cur);
}

// ---------------------------------------------------------------------------
// Observability e2e: daemon → submit → result → `report --html`
// ---------------------------------------------------------------------------

/// Artifact directory for the e2e: `KF_E2E_REPORT_DIR` when set (CI
/// keeps and uploads it), else a per-process temp subdirectory.
fn report_dir() -> (PathBuf, bool) {
    match std::env::var("KF_E2E_REPORT_DIR") {
        Ok(dir) => (PathBuf::from(dir), true),
        Err(_) => (
            std::env::temp_dir().join(format!("kf_report_e2e_{}", std::process::id())),
            false,
        ),
    }
}

fn submit(client: &mut Client, spec: JobSpec) -> u64 {
    let resp = client.request(&Request::Submit(spec)).expect("submit rpc");
    assert!(proto::response_ok(&resp), "submit failed: {resp}");
    resp.get("job_id").and_then(|v| v.as_usize()).expect("job_id") as u64
}

fn poll_to_completion(client: &mut Client, id: u64) -> String {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let resp = client.request(&Request::Status(id)).expect("status rpc");
        assert!(proto::response_ok(&resp), "status failed: {resp}");
        let state = resp.get("state").and_then(|s| s.as_str()).unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "cancelled") {
            return state;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in state {state}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn fetch_result(client: &mut Client, id: u64) -> Json {
    let resp = client.request(&Request::Result(id)).expect("result rpc");
    assert!(proto::response_ok(&resp), "result failed: {resp}");
    resp
}

#[test]
fn e2e_report_html_covers_every_lifecycle_stage_and_view() {
    let (dir, keep) = report_dir();
    std::fs::create_dir_all(&dir).expect("report dir");
    let db = dir.join("e2e.db.jsonl");
    let journal = dir.join("e2e.journal.jsonl");
    let trace = dir.join("e2e.trace.jsonl");
    let slog = dir.join("e2e.search.jsonl");
    let html_path = dir.join("e2e.report.html");
    for p in [&db, &journal, &trace, &slog, &html_path] {
        let _ = std::fs::remove_file(p);
    }

    // A fully-instrumented daemon: results db + journal + trace +
    // search history, exactly as CI runs it.
    let service = KernelService::start(ServiceConfig {
        devices: vec![DeviceProfile::b580()],
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 16,
        db_path: Some(db.clone()),
        journal_path: Some(journal.clone()),
        trace_path: Some(trace.clone()),
        search_log_path: Some(slog.clone()),
        ..ServiceConfig::default()
    })
    .expect("service starts");
    let mut server = Server::start(Arc::clone(&service), "127.0.0.1:0").expect("server binds");
    let mut client = Client::connect(&server.addr().to_string()).expect("client connects");

    let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
    spec.iters = 3;
    spec.population = 2;
    let id = submit(&mut client, spec);
    assert_eq!(poll_to_completion(&mut client, id), "done");
    fetch_result(&mut client, id); // emits the terminal `responded` stage

    server.shutdown();
    server.wait();
    service.stop();

    // Every lifecycle stage of the happy path reached the trace sink.
    let events = TraceSink::load(&trace);
    for s in [
        stage::SUBMIT,
        stage::QUEUED,
        stage::DISPATCHED,
        stage::COMPILED,
        stage::EXECUTED,
        stage::COMMITTED,
        stage::RESPONDED,
    ] {
        assert!(
            events.iter().any(|e| e.stage == s),
            "stage {s} missing from trace: {events:?}"
        );
    }

    // The engine logged one row per generation, labeled by cache key.
    let history = SearchLog::load(&slog);
    assert_eq!(history.len(), 3, "one row per generation: {history:?}");
    for (generation, row) in history.iter().enumerate() {
        assert_eq!(row.generation, generation);
        assert_eq!(row.device, "b580");
        assert!(row.run.contains("20_LeakyReLU"), "run label joins the db: {}", row.run);
    }

    // The real binary renders the dashboard from the run's artifacts.
    let out = report_cmd(&[
        "report",
        "--db",
        db.to_str().unwrap(),
        "--trace",
        trace.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--search-log",
        slog.to_str().unwrap(),
        "--html",
        html_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "report --html failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let html = std::fs::read_to_string(&html_path).expect("dashboard written");

    for s in stage::ALL {
        assert!(html.contains(s), "stage {s} missing from dashboard");
    }
    for title in [
        "Job lifecycle coverage",
        "Speedup trajectories",
        "Latency breakdown",
        "Reliability",
        "Search health",
    ] {
        assert!(html.contains(title), "{title} section missing from dashboard");
    }
    assert!(html.contains("20_LeakyReLU"), "search-health run row present");
    assert!(html.contains("b580"), "device lane present");
    assert!(html.contains("<svg"), "sparklines are inline SVG");
    assert!(!html.contains("<script"), "dashboard carries no JS");

    // The regression gate runs clean against the run's own database.
    let out = report_cmd(&[
        "report",
        "regressions",
        "--db",
        db.to_str().unwrap(),
        "--baseline",
        db.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    if !keep {
        let _ = std::fs::remove_dir_all(&dir);
    }
}
