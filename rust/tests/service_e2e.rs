//! End-to-end service tests: a real daemon on an ephemeral loopback
//! port, driven through the newline-JSON TCP RPC exactly as the
//! `kernelfoundry submit` client drives it.

use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::obs::{stage, TraceSink};
use kernelfoundry::service::{
    proto, Client, DeviceTarget, JobSpec, KernelService, Request, Server, ServiceConfig,
    TaskSource,
};
use kernelfoundry::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn start_daemon(devices: Vec<DeviceProfile>) -> (Arc<KernelService>, Server) {
    let service = KernelService::start(ServiceConfig {
        devices,
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 16,
        ..ServiceConfig::default()
    })
    .expect("service starts");
    let server = Server::start(Arc::clone(&service), "127.0.0.1:0").expect("server binds");
    (service, server)
}

fn connect(server: &Server) -> Client {
    Client::connect(&server.addr().to_string()).expect("client connects")
}

fn tiny_spec(task: &str, device: &str) -> JobSpec {
    let mut spec = JobSpec::catalog(task, device);
    spec.iters = 3;
    spec.population = 2;
    spec
}

/// Submit over the wire; returns the job id.
fn submit(client: &mut Client, spec: JobSpec) -> u64 {
    let resp = client.request(&Request::Submit(spec)).expect("submit rpc");
    assert!(proto::response_ok(&resp), "submit failed: {resp}");
    resp.get("job_id").and_then(|v| v.as_usize()).expect("job_id") as u64
}

/// Poll `status` until the job reaches a terminal state.
fn poll_to_completion(client: &mut Client, id: u64) -> String {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let resp = client.request(&Request::Status(id)).expect("status rpc");
        assert!(proto::response_ok(&resp), "status failed: {resp}");
        let state = resp.get("state").and_then(|s| s.as_str()).unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "cancelled") {
            return state;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in state {state}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn fetch_result(client: &mut Client, id: u64) -> Json {
    let resp = client.request(&Request::Result(id)).expect("result rpc");
    assert!(proto::response_ok(&resp), "result failed: {resp}");
    resp
}

fn stat_u64(stats: &Json, path: &str) -> u64 {
    stats
        .get_path(path)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing {path} in {stats}")) as u64
}

/// The acceptance-criteria round trip: a catalog job returns a
/// best-kernel result over loopback TCP, and an identical resubmission
/// is served from the cache (verified via the `stats` hit counter).
#[test]
fn catalog_job_roundtrip_and_cache_hit() {
    let (service, mut server) = start_daemon(vec![DeviceProfile::b580()]);
    let mut client = connect(&server);

    let id = submit(&mut client, tiny_spec("20_LeakyReLU", "b580"));
    assert_eq!(poll_to_completion(&mut client, id), "done");
    let result = fetch_result(&mut client, id);
    let units = result.get("results").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 1);
    let r = &units[0];
    assert_eq!(r.get("device").unwrap().as_str(), Some("b580"));
    assert_eq!(r.get("task_id").unwrap().as_str(), Some("20_LeakyReLU"));
    assert_eq!(r.get("evaluations").unwrap().as_usize(), Some(6), "3 gens x pop 2");
    assert_eq!(r.get("cached").unwrap().as_bool(), Some(false));
    // A best-kernel result: when a correct kernel was found its source
    // rides along; either way the metrics block is complete.
    if r.get("correct").unwrap().as_bool() == Some(true) {
        assert!(!r.get("source").unwrap().as_str().unwrap().is_empty());
        assert!(r.get("speedup").unwrap().as_f64().unwrap() > 0.0);
    }

    let stats = client.request(&Request::Stats).unwrap();
    let hits_before = stat_u64(&stats, "cache.hits");
    assert_eq!(hits_before, 0, "no hits yet: {stats}");
    assert_eq!(stat_u64(&stats, "cache.entries"), 1);

    // Identical resubmission: served from the cache, done immediately.
    let resp = client
        .request(&Request::Submit(tiny_spec("20_LeakyReLU", "b580")))
        .unwrap();
    assert!(proto::response_ok(&resp), "{resp}");
    assert_eq!(resp.get("state").unwrap().as_str(), Some("done"));
    assert_eq!(resp.get("cached").unwrap().as_bool(), Some(true));
    let id2 = resp.get("job_id").unwrap().as_usize().unwrap() as u64;
    let result2 = fetch_result(&mut client, id2);
    let r2 = &result2.get("results").unwrap().as_arr().unwrap()[0];
    assert_eq!(r2.get("cached").unwrap().as_bool(), Some(true));

    let stats = client.request(&Request::Stats).unwrap();
    assert_eq!(stat_u64(&stats, "cache.hits"), 1, "resubmission hit the cache: {stats}");

    server.shutdown();
    server.wait();
    service.stop();
}

/// The paper's user input layer over the wire: an inline App. C custom
/// task bundle (config + marked source) evolves like a catalog task.
#[test]
fn inline_custom_task_job() {
    let (service, mut server) = start_daemon(vec![DeviceProfile::b580()]);
    let mut client = connect(&server);

    let spec = JobSpec {
        task: TaskSource::Custom {
            config: "name: wire_rope\nworkload:\n  - op: rope\n    elems: 1048576\n".to_string(),
            source: "### KF:REFERENCE ###\ndef rope(q, cos, sin): return q * cos\n\
                     ### KF:INSTRUCTIONS ###\nOptimize for the B580.\n### KF:END ###\n"
                .to_string(),
        },
        device: DeviceTarget::Named("b580".to_string()),
        language: "sycl".to_string(),
        seed: 11,
        iters: 3,
        population: 2,
        priority: kernelfoundry::service::JobPriority::Normal,
    };
    let id = submit(&mut client, spec.clone());
    assert_eq!(poll_to_completion(&mut client, id), "done");
    let result = fetch_result(&mut client, id);
    let r = &result.get("results").unwrap().as_arr().unwrap()[0];
    assert_eq!(r.get("task_id").unwrap().as_str(), Some("wire_rope"));

    // Identical custom bundle → content-addressed cache hit.
    let resp = client.request(&Request::Submit(spec)).unwrap();
    assert_eq!(resp.get("cached").unwrap().as_bool(), Some(true), "{resp}");

    // A malformed bundle is rejected at submit time with a parse error.
    let bad = JobSpec {
        task: TaskSource::Custom {
            config: "name: broken\n".to_string(), // no workload
            source: "### KF:REFERENCE ###\nref\n### KF:END ###\n".to_string(),
        },
        ..tiny_spec("20_LeakyReLU", "b580")
    };
    let resp = client.request(&Request::Submit(bad)).unwrap();
    assert!(!proto::response_ok(&resp));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("custom task"));

    server.shutdown();
    server.wait();
    service.stop();
}

/// Cancelling a queued job works; cancelling it again (or a finished
/// job) is an error.
#[test]
fn cancel_queued_job() {
    let (service, mut server) = start_daemon(vec![DeviceProfile::b580()]);
    let mut client = connect(&server);

    // Occupy the single lane with a long job, then queue a second one
    // behind it — the second must still be cancellable.
    let mut long = tiny_spec("1_Conv2D_ReLU_BiasAdd", "b580");
    long.iters = 20;
    long.population = 8;
    let first = submit(&mut client, long);
    let second = submit(&mut client, tiny_spec("20_LeakyReLU", "b580"));

    let resp = client.request(&Request::Cancel(second)).unwrap();
    assert!(proto::response_ok(&resp), "cancel failed: {resp}");
    assert_eq!(resp.get("state").unwrap().as_str(), Some("cancelled"));
    assert_eq!(poll_to_completion(&mut client, second), "cancelled");

    // Double-cancel is an error.
    let resp = client.request(&Request::Cancel(second)).unwrap();
    assert!(!proto::response_ok(&resp));

    // The long job is unaffected and completes.
    assert_eq!(poll_to_completion(&mut client, first), "done");
    let resp = client.request(&Request::Cancel(first)).unwrap();
    assert!(!proto::response_ok(&resp), "finished jobs cannot be cancelled");

    let stats = client.request(&Request::Stats).unwrap();
    assert_eq!(stat_u64(&stats, "jobs.cancelled"), 1, "{stats}");
    assert_eq!(stat_u64(&stats, "jobs.done"), 1, "{stats}");

    server.shutdown();
    server.wait();
    service.stop();
}

/// A fan-out job returns one result per fleet device (the acceptance
/// criterion's cross-hardware comparison).
#[test]
fn fan_out_returns_one_result_per_device() {
    let (service, mut server) =
        start_daemon(vec![DeviceProfile::lnl(), DeviceProfile::b580(), DeviceProfile::a6000()]);
    let mut client = connect(&server);

    let mut spec = tiny_spec("20_LeakyReLU", "b580");
    spec.device = DeviceTarget::FanOut;
    let id = submit(&mut client, spec);
    assert_eq!(poll_to_completion(&mut client, id), "done");
    let result = fetch_result(&mut client, id);
    let units = result.get("results").unwrap().as_arr().unwrap();
    assert_eq!(units.len(), 3, "one result per fleet device");
    let mut devices: Vec<&str> = units
        .iter()
        .map(|r| r.get("device").unwrap().as_str().unwrap())
        .collect();
    devices.sort_unstable();
    assert_eq!(devices, vec!["a6000", "b580", "lnl"]);

    // Per-device utilization is reported for every lane.
    let stats = client.request(&Request::Stats).unwrap();
    let fleet = stats.get("fleet").unwrap().as_arr().unwrap();
    assert_eq!(fleet.len(), 3);
    for lane in fleet {
        assert_eq!(lane.get("units_done").unwrap().as_f64(), Some(1.0), "{stats}");
        assert!(lane.get("utilization").unwrap().as_f64().unwrap() >= 0.0);
    }

    server.shutdown();
    server.wait();
    service.stop();
}

/// Durability round trip (the journal satellite): submit N jobs to a
/// journaled daemon, shut it down cleanly, restart a second daemon on
/// the same journal + db, and every result is retrievable over the
/// wire without re-execution — zero lost jobs, monotone job ids.
#[test]
fn journal_restart_round_trip() {
    let dir = std::env::temp_dir();
    let journal = dir.join(format!("kf_e2e_restart_{}.journal.jsonl", std::process::id()));
    let db = dir.join(format!("kf_e2e_restart_{}.db.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
    let cfg = || ServiceConfig {
        devices: vec![DeviceProfile::b580()],
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 16,
        db_path: Some(db.clone()),
        journal_path: Some(journal.clone()),
        ..ServiceConfig::default()
    };

    const N: u64 = 3;
    {
        let service = KernelService::start(cfg()).expect("first daemon starts");
        let mut server = Server::start(Arc::clone(&service), "127.0.0.1:0").unwrap();
        let mut client = connect(&server);
        for i in 0..N {
            let mut spec = tiny_spec("20_LeakyReLU", "b580");
            spec.seed = 100 + i;
            let id = submit(&mut client, spec);
            assert_eq!(id, i + 1);
            assert_eq!(poll_to_completion(&mut client, id), "done");
        }
        server.shutdown();
        server.wait();
        service.stop(); // clean shutdown: lease released, commits durable
    }

    let service = KernelService::start(cfg()).expect("restart against the same journal");
    let mut server = Server::start(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let mut client = connect(&server);

    // Every pre-restart job is retrievable with its full result.
    for id in 1..=N {
        let result = fetch_result(&mut client, id);
        assert_eq!(result.get("state").unwrap().as_str(), Some("done"), "{result}");
        let units = result.get("results").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].get("device").unwrap().as_str(), Some("b580"));
    }

    let stats = client.request(&Request::Stats).unwrap();
    assert_eq!(stat_u64(&stats, "journal.replayed_jobs"), N, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.restored_results"), N, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.requeued_units"), 0, "{stats}");
    assert_eq!(stat_u64(&stats, "journal.lost_jobs"), 0, "zero lost jobs: {stats}");
    // Replay restored the results without re-running anything.
    let fleet = stats.get("fleet").unwrap().as_arr().unwrap();
    assert_eq!(fleet[0].get("units_done").unwrap().as_f64(), Some(0.0), "{stats}");

    // Ids keep counting from the journal's high-water mark.
    let mut spec = tiny_spec("20_LeakyReLU", "b580");
    spec.seed = 100; // same line as job 1 → cache hit survives the restart
    let resp = client.request(&Request::Submit(spec)).unwrap();
    assert!(proto::response_ok(&resp), "{resp}");
    assert_eq!(resp.get("job_id").unwrap().as_usize(), Some(N as usize + 1));
    assert_eq!(resp.get("cached").unwrap().as_bool(), Some(true), "{resp}");

    server.shutdown();
    server.wait();
    service.stop();
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&db);
}

/// Trace-sink location for an e2e test: `KF_E2E_TRACE_DIR` when set (CI
/// points this at a directory it inspects after the suite), else the
/// system temp dir. Files under the env dir are kept for CI's
/// committed-event check; temp-dir files are cleaned up by the test.
fn trace_sink_for(name: &str) -> (PathBuf, bool) {
    match std::env::var("KF_E2E_TRACE_DIR") {
        Ok(dir) => {
            let dir = PathBuf::from(dir);
            let _ = std::fs::create_dir_all(&dir);
            (dir.join(format!("kf_e2e_{name}.trace.jsonl")), true)
        }
        Err(_) => (
            std::env::temp_dir().join(format!("kf_e2e_{name}_{}.trace.jsonl", std::process::id())),
            false,
        ),
    }
}

fn start_traced_daemon(name: &str) -> (Arc<KernelService>, Server, PathBuf, bool) {
    let (path, keep) = trace_sink_for(name);
    let _ = std::fs::remove_file(&path);
    let service = KernelService::start(ServiceConfig {
        devices: vec![DeviceProfile::b580()],
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 16,
        trace_path: Some(path.clone()),
        ..ServiceConfig::default()
    })
    .expect("service starts");
    let server = Server::start(Arc::clone(&service), "127.0.0.1:0").expect("server binds");
    (service, server, path, keep)
}

/// One Prometheus sample's value (exact-name match; labeled series and
/// `_bucket`/`_count` suffixes never collide because of the space).
fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| {
            l.strip_prefix(name)
                .and_then(|rest| rest.strip_prefix(' '))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or_else(|| panic!("metric {name} missing from exposition:\n{text}"))
}

/// Acceptance criterion: after a submit/result round trip, the `metrics`
/// RPC verb returns Prometheus text exposition with queue gauges, cache
/// counters and nonzero per-stage lifecycle histograms with p50/p99
/// summaries.
#[test]
fn metrics_verb_reports_lifecycle_histograms() {
    let (service, mut server, trace, keep) = start_traced_daemon("metrics");
    let mut client = connect(&server);

    let id = submit(&mut client, tiny_spec("20_LeakyReLU", "b580"));
    assert_eq!(poll_to_completion(&mut client, id), "done");
    fetch_result(&mut client, id);

    let resp = client.request(&Request::Metrics(None)).expect("metrics rpc");
    assert!(proto::response_ok(&resp), "{resp}");
    let text = resp.get("prometheus").unwrap().as_str().unwrap().to_string();

    // Queue gauges and cache counters.
    assert!(text.contains("# TYPE kf_queue_depth gauge"), "{text}");
    assert_eq!(metric_value(&text, "kf_queue_capacity"), 16.0);
    assert_eq!(metric_value(&text, "kf_jobs_submitted_total"), 1.0);
    assert_eq!(metric_value(&text, "kf_cache_misses_total"), 1.0);
    assert_eq!(metric_value(&text, "kf_cache_hits_total"), 0.0);

    // Nonzero lifecycle histograms with quantile summaries.
    for h in ["kf_stage_queued_ms", "kf_stage_run_ms", "kf_job_submit_to_responded_ms"] {
        assert!(text.contains(&format!("# TYPE {h} histogram")), "{h} missing:\n{text}");
        assert!(metric_value(&text, &format!("{h}_count")) >= 1.0, "{h} empty:\n{text}");
        assert!(metric_value(&text, &format!("{h}_p50")) >= 0.0);
        let (p50, p99) = (
            metric_value(&text, &format!("{h}_p50")),
            metric_value(&text, &format!("{h}_p99")),
        );
        assert!(p99 >= p50, "{h}: p99 {p99} < p50 {p50}");
    }
    // The RPC layer measures itself, and the fleet labels its lanes.
    assert!(metric_value(&text, "kf_rpc_handle_ms_count") >= 1.0);
    assert!(text.contains("kf_lane_units_done_total{device=\"b580\"} 1"), "{text}");

    server.shutdown();
    server.wait();
    service.stop();
    if !keep {
        let _ = std::fs::remove_file(&trace);
    }
}

/// Acceptance criterion: `trace <job-id>` reconstructs a monotonically
/// ordered submit → responded timeline from the sink after a
/// submit/result round trip; a cached resubmission still records a
/// terminal `committed`.
#[test]
fn trace_timeline_is_monotone_and_complete() {
    let (service, mut server, trace, keep) = start_traced_daemon("timeline");
    let mut client = connect(&server);

    let id = submit(&mut client, tiny_spec("20_LeakyReLU", "b580"));
    assert_eq!(poll_to_completion(&mut client, id), "done");
    fetch_result(&mut client, id);

    let timeline = TraceSink::timeline(&trace, id);
    let stages: Vec<&str> = timeline.iter().map(|e| e.stage.as_str()).collect();
    assert_eq!(
        stages,
        vec![
            stage::SUBMIT,
            stage::QUEUED,
            stage::DISPATCHED,
            stage::COMPILED,
            stage::EXECUTED,
            stage::COMMITTED,
            stage::RESPONDED,
        ],
        "full lifecycle in order"
    );
    assert!(
        timeline.windows(2).all(|w| w[0].ts_ms <= w[1].ts_ms),
        "timestamps are monotone: {timeline:?}"
    );
    let tid = &timeline[0].trace_id;
    assert!(timeline.iter().all(|e| &e.trace_id == tid), "one trace id per job");
    assert_eq!(timeline[2].device.as_deref(), Some("b580"), "dispatch is device-scoped");

    // A cache-hit resubmission never visits a lane but still commits.
    let resp = client.request(&Request::Submit(tiny_spec("20_LeakyReLU", "b580"))).unwrap();
    assert_eq!(resp.get("cached").unwrap().as_bool(), Some(true), "{resp}");
    let id2 = resp.get("job_id").unwrap().as_usize().unwrap() as u64;
    fetch_result(&mut client, id2);
    let cached_stages: Vec<String> =
        TraceSink::timeline(&trace, id2).iter().map(|e| e.stage.clone()).collect();
    assert_eq!(cached_stages, vec![stage::SUBMIT, stage::COMMITTED, stage::RESPONDED]);

    server.shutdown();
    server.wait();
    service.stop();
    if !keep {
        let _ = std::fs::remove_file(&trace);
    }
}

/// Wire-level robustness: unknown tasks, unknown devices, unknown job
/// ids and unfinished results all produce structured errors, and the
/// RPC `shutdown` verb stops the daemon.
#[test]
fn error_paths_and_rpc_shutdown() {
    let (service, mut server) = start_daemon(vec![DeviceProfile::b580()]);
    let mut client = connect(&server);

    let resp = client
        .request(&Request::Submit(tiny_spec("no_such_task", "b580")))
        .unwrap();
    assert!(!proto::response_ok(&resp));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("unknown task"));

    let resp = client
        .request(&Request::Submit(tiny_spec("20_LeakyReLU", "h100")))
        .unwrap();
    assert!(!proto::response_ok(&resp));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("not in fleet"));

    let resp = client.request(&Request::Status(99)).unwrap();
    assert!(!proto::response_ok(&resp));

    let id = submit(&mut client, tiny_spec("20_LeakyReLU", "b580"));
    poll_to_completion(&mut client, id);

    // Shutdown via RPC: the daemon acknowledges, the accept loop exits.
    let resp = client.request(&Request::Shutdown).unwrap();
    assert!(proto::response_ok(&resp));
    server.wait();
    service.stop();
}
