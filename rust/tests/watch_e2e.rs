//! End-to-end test for the live-observability stack (ISSUE 9): a real
//! daemon with a tight SLO rule, driven over loopback TCP, must stream
//! — on ONE `watch` connection — metric-delta frames, a job's lifecycle
//! trace events, and an alert `firing` → `resolved` pair.
//!
//! The breach is forced deterministically through `cache_hit_rate`: the
//! first job is a cache miss (rate 0, breaching `> 0.2` with no
//! debounce), and an identical resubmission is a cache hit (rate 0.5,
//! healed). No timing races: the counters only move when the test
//! submits.
//!
//! CI points `KF_E2E_TRACE_DIR` / `KF_E2E_ALERT_DIR` at directories it
//! inspects after the suite (`scripts/check_traces.py`,
//! `scripts/check_alerts.py`); without them the artifacts land in the
//! system temp dir and are cleaned up.

use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::obs::alerts::AlertLog;
use kernelfoundry::obs::stage;
use kernelfoundry::service::{proto, Client, JobSpec, KernelService, Server, ServiceConfig};
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Artifact location: under `$env` when set (kept for CI), else the
/// system temp dir (cleaned up by the test).
fn artifact_path(env: &str, name: &str) -> (PathBuf, bool) {
    match std::env::var(env) {
        Ok(dir) => {
            let dir = PathBuf::from(dir);
            let _ = std::fs::create_dir_all(&dir);
            (dir.join(name), true)
        }
        Err(_) => (
            std::env::temp_dir().join(format!("kf_watch_{}_{name}", std::process::id())),
            false,
        ),
    }
}

/// Everything observed on the watch stream so far.
#[derive(Default)]
struct FrameLog {
    metrics: usize,
    stages: BTreeSet<String>,
    alerts: Vec<(String, String)>,
    firing: bool,
    resolved: bool,
}

/// Drain frames until `done(log)`; metrics frames keep arriving every
/// interval, so the deadline check between reads always gets a turn.
fn read_until(watcher: &mut Client, log: &mut FrameLog, done: impl Fn(&FrameLog) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !done(log) {
        assert!(
            Instant::now() < deadline,
            "timed out waiting for frames: {} metrics, stages {:?}, alerts {:?}",
            log.metrics,
            log.stages,
            log.alerts
        );
        let frame = watcher.next_frame().expect("read frame").expect("stream stays open");
        match frame.get("kind").and_then(|k| k.as_str()) {
            Some("metrics") => log.metrics += 1,
            Some("trace") => {
                if let Some(t) = frame.get("t").and_then(|v| v.as_str()) {
                    log.stages.insert(t.to_string());
                }
            }
            Some("alert") => {
                let get = |k: &str| frame.get(k).and_then(|v| v.as_str()).unwrap_or("?");
                let state = get("state").to_string();
                log.firing |= state == "firing";
                log.resolved |= state == "resolved";
                let rule = get("rule").to_string();
                log.alerts.push((rule, state));
            }
            _ => {}
        }
    }
}

#[test]
fn watch_stream_carries_metrics_traces_and_an_alert_pair() {
    let (trace_path, keep_trace) = artifact_path("KF_E2E_TRACE_DIR", "kf_e2e_watch.trace.jsonl");
    let (alert_path, keep_alerts) = artifact_path("KF_E2E_ALERT_DIR", "kf_e2e_watch.alerts.jsonl");
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_file(&alert_path);
    let rules_path =
        std::env::temp_dir().join(format!("kf_watch_rules_{}.txt", std::process::id()));
    std::fs::write(&rules_path, "cache: cache_hit_rate > 0.2\n").expect("write rules");

    let service = KernelService::start(ServiceConfig {
        devices: vec![DeviceProfile::b580()],
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 16,
        trace_path: Some(trace_path.clone()),
        alert_rules_path: Some(rules_path.clone()),
        alert_log_path: Some(alert_path.clone()),
        alert_interval: Duration::from_millis(20),
        ..ServiceConfig::default()
    })
    .expect("service starts");
    let mut server = Server::start(Arc::clone(&service), "127.0.0.1:0").expect("server binds");

    // The ONE watching connection, opened before any job exists so it
    // observes the whole story.
    let mut watcher = Client::connect(&server.addr().to_string()).expect("watcher connects");
    watcher.send(&proto::Request::Watch(50)).expect("watch verb");
    let hello = watcher.next_frame().expect("read hello").expect("hello frame");
    assert!(proto::response_ok(&hello), "{hello}");
    assert_eq!(hello.get("kind").unwrap().as_str(), Some("hello"));
    let rules: Vec<String> = hello
        .get("alert_rules")
        .and_then(|r| r.as_arr())
        .map(|arr| arr.iter().filter_map(|v| v.as_str()).map(String::from).collect())
        .unwrap_or_default();
    assert_eq!(rules, ["cache"], "hello advertises the loaded rule set");

    // A separate driving connection submits the jobs.
    let mut driver = Client::connect(&server.addr().to_string()).expect("driver connects");
    let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
    spec.iters = 2;
    spec.population = 2;
    let resp = driver.request(&proto::Request::Submit(spec.clone())).expect("submit");
    assert!(proto::response_ok(&resp), "{resp}");
    let id = resp.get("job_id").unwrap().as_usize().unwrap() as u64;
    service.wait(id, Duration::from_secs(60)).expect("job finishes");

    // The miss leaves cache_hit_rate at 0: the rule breaches and (no
    // debounce) the next alert tick fires. The breach is sticky until
    // the resubmission below, so draining to the firing frame is safe.
    let mut seen = FrameLog::default();
    read_until(&mut watcher, &mut seen, |s| s.firing);

    // Identical resubmission: a cache hit lifts the rate to 0.5 > 0.2.
    let resp = driver.request(&proto::Request::Submit(spec)).expect("resubmit");
    assert!(proto::response_ok(&resp), "{resp}");
    assert_eq!(resp.get("cached").unwrap().as_bool(), Some(true), "{resp}");
    read_until(&mut watcher, &mut seen, |s| s.resolved);

    // One connection saw all three frame kinds.
    assert!(seen.metrics > 0, "no metric-delta frames");
    for want in [stage::SUBMIT, stage::DISPATCHED, stage::COMMITTED] {
        assert!(seen.stages.contains(want), "stage {want} missing: {:?}", seen.stages);
    }
    let edges: Vec<&str> = seen.alerts.iter().map(|(_, s)| s.as_str()).collect();
    assert_eq!(edges, ["firing", "resolved"], "exactly one breach cycle: {:?}", seen.alerts);
    assert!(seen.alerts.iter().all(|(r, _)| r == "cache"));

    // The same pair landed in the durable alert log, in order, with
    // monotone timestamps.
    let logged = AlertLog::load(&alert_path);
    assert_eq!(logged.len(), 2, "{logged:?}");
    assert_eq!(logged[0].state, "firing");
    assert_eq!(logged[1].state, "resolved");
    assert!(logged[0].ts_ms <= logged[1].ts_ms);
    assert_eq!(logged[0].rule, "cache");

    drop(watcher);
    server.shutdown();
    server.wait();
    service.stop();
    let _ = std::fs::remove_file(&rules_path);
    if !keep_trace {
        let _ = std::fs::remove_file(&trace_path);
    }
    if !keep_alerts {
        let _ = std::fs::remove_file(&alert_path);
    }
}
