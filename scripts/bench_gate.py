#!/usr/bin/env python3
"""Service-throughput regression gate (stdlib only).

Compares a freshly-measured ``BENCH_service.json`` against the committed
baseline and fails (exit 1) on a >2x throughput regression in either the
cold (execution) or warm (cache-hit) wave. Either way it prints a
per-metric delta table — baseline, current, and percent change — so a
CI log always shows *how far* each metric moved, not just pass/fail.

Bootstrap mode: the first committed baseline carries ``"measured": false``
(this repo's build environment has no Rust toolchain, so the seed baseline
cannot carry honest numbers). An unmeasured baseline disables the
comparison — the gate prints the table with a dash for the baseline
column — and CI stays green until a measured baseline is promoted with
``make bench-baseline``.

A metric key absent from the current run (or from the baseline) is never
fatal: it gets a per-key ``missing``/``n/a`` row in the table, and the
gate exits nonzero only for genuinely regressed keys.

Usage:
    python3 scripts/bench_gate.py --baseline <committed.json> --current BENCH_service.json
"""

import argparse
import json
import sys

# A regression worse than this factor vs baseline fails the gate.
MAX_REGRESSION = 2.0

GATED_METRICS = ("cold_jobs_per_sec", "warm_jobs_per_sec")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench gate: cannot read {path}: {e}")


def numeric(value):
    """True for real numbers (bool is an int subclass — exclude it)."""
    return isinstance(value, (int, float)) and not isinstance(value, bool)


def gated_keys(baseline):
    """GATED_METRICS plus any extra numeric rate key the baseline carries.

    A baseline that grows a new `*_per_sec` metric gets it reported in
    the table automatically instead of being silently ignored.
    """
    keys = list(GATED_METRICS)
    for key in sorted(baseline):
        if key not in keys and key.endswith("_per_sec") and numeric(baseline[key]):
            keys.append(key)
    return keys


def delta_rows(baseline, current, measured):
    """One (metric, baseline, current, delta%, status) row per metric.

    Higher is better for every gated metric, so a negative delta is a
    slowdown; `status` is FAIL only when the slowdown factor exceeds
    MAX_REGRESSION against a measured baseline. A key absent (or
    non-positive) on either side gets a visible `missing`/`n/a` row —
    never a hard failure: the gate fails only on regressed keys.
    """
    rows = []
    for metric in gated_keys(baseline):
        cur = current.get(metric)
        base = baseline.get(metric) if measured else None
        base_txt = f"{base:.2f}" if numeric(base) else "-"
        if not (numeric(cur) and cur > 0):
            rows.append((metric, base_txt, "-", "-", "missing"))
        elif numeric(base) and base > 0:
            delta_pct = (cur - base) / base * 100.0
            status = "FAIL" if base / cur > MAX_REGRESSION else "ok"
            rows.append((metric, base_txt, f"{cur:.2f}",
                         f"{delta_pct:+.1f}%", status))
        else:
            rows.append((metric, "-", f"{cur:.2f}", "-", "n/a"))
    return rows


def print_table(rows):
    headers = ("metric", "baseline", "current", "delta", "status")
    widths = [max(len(str(r[i])) for r in rows + [headers])
              for i in range(len(headers))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print("bench gate: " + fmt.format(*headers))
    for row in rows:
        print("bench gate: " + fmt.format(*row))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--current", required=True, help="freshly-measured bench JSON")
    args = ap.parse_args()

    baseline = load(args.baseline)
    current = load(args.current)

    measured = bool(baseline.get("measured", False))
    rows = delta_rows(baseline, current, measured)
    print_table(rows)

    missing = [row[0] for row in rows if row[4] == "missing"]
    if missing:
        print("bench gate: reported but not fatal — missing in current run: "
              + ", ".join(missing))

    if not measured:
        print("bench gate: baseline is a bootstrap placeholder (measured=false);")
        print("bench gate: comparison skipped.")
        print("bench gate: promote a measured baseline with `make bench-baseline`.")
        return

    failures = [row[0] for row in rows if row[4] == "FAIL"]
    if failures:
        sys.exit(f"bench gate: >{MAX_REGRESSION:.0f}x throughput regression in: "
                 + ", ".join(failures))
    print("bench gate: within budget.")


if __name__ == "__main__":
    main()
