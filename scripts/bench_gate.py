#!/usr/bin/env python3
"""Service-throughput regression gate (stdlib only).

Compares a freshly-measured ``BENCH_service.json`` against the committed
baseline and fails (exit 1) on a >2x throughput regression in either the
cold (execution) or warm (cache-hit) wave.

Bootstrap mode: the first committed baseline carries ``"measured": false``
(this repo's build environment has no Rust toolchain, so the seed baseline
cannot carry honest numbers). An unmeasured baseline disables the
comparison — the gate only validates the current file's shape — and CI
stays green until a measured baseline is promoted with
``make bench-baseline``.

Usage:
    python3 scripts/bench_gate.py --baseline <committed.json> --current BENCH_service.json
"""

import argparse
import json
import sys

# A regression worse than this factor vs baseline fails the gate.
MAX_REGRESSION = 2.0

GATED_METRICS = ("cold_jobs_per_sec", "warm_jobs_per_sec")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench gate: cannot read {path}: {e}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--current", required=True, help="freshly-measured bench JSON")
    args = ap.parse_args()

    baseline = load(args.baseline)
    current = load(args.current)

    for metric in GATED_METRICS:
        value = current.get(metric)
        if not isinstance(value, (int, float)) or value <= 0:
            sys.exit(f"bench gate: current {metric} missing or non-positive: {value!r}")

    if not baseline.get("measured", False):
        print("bench gate: baseline is a bootstrap placeholder (measured=false);")
        print("bench gate: shape check passed, comparison skipped.")
        print("bench gate: promote a measured baseline with `make bench-baseline`.")
        return

    failures = []
    for metric in GATED_METRICS:
        base = baseline.get(metric, 0.0)
        cur = current[metric]
        if base <= 0:
            continue
        ratio = base / cur
        status = "FAIL" if ratio > MAX_REGRESSION else "ok"
        print(f"bench gate: {metric}: baseline {base:.2f} -> current {cur:.2f} "
              f"({ratio:.2f}x slower) [{status}]")
        if ratio > MAX_REGRESSION:
            failures.append(metric)

    if failures:
        sys.exit(f"bench gate: >{MAX_REGRESSION:.0f}x throughput regression in: "
                 + ", ".join(failures))
    print("bench gate: within budget.")


if __name__ == "__main__":
    main()
