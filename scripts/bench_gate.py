#!/usr/bin/env python3
"""Service-throughput regression gate (stdlib only).

Compares a freshly-measured ``BENCH_service.json`` against the committed
baseline and fails (exit 1) on a >2x throughput regression in either the
cold (execution) or warm (cache-hit) wave. Either way it prints a
per-metric delta table — baseline, current, and percent change — so a
CI log always shows *how far* each metric moved, not just pass/fail.

Bootstrap mode: the first committed baseline carries ``"measured": false``
(this repo's build environment has no Rust toolchain, so the seed baseline
cannot carry honest numbers). An unmeasured baseline disables the
comparison — the gate only validates the current file's shape and prints
the table with a dash for the baseline column — and CI stays green until
a measured baseline is promoted with ``make bench-baseline``.

Usage:
    python3 scripts/bench_gate.py --baseline <committed.json> --current BENCH_service.json
"""

import argparse
import json
import sys

# A regression worse than this factor vs baseline fails the gate.
MAX_REGRESSION = 2.0

GATED_METRICS = ("cold_jobs_per_sec", "warm_jobs_per_sec")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench gate: cannot read {path}: {e}")


def delta_rows(baseline, current, measured):
    """One (metric, baseline, current, delta%, status) row per metric.

    Higher is better for every gated metric, so a negative delta is a
    slowdown; `status` is FAIL only when the slowdown factor exceeds
    MAX_REGRESSION against a measured baseline.
    """
    rows = []
    for metric in GATED_METRICS:
        cur = current[metric]
        base = baseline.get(metric) if measured else None
        if isinstance(base, (int, float)) and base > 0:
            delta_pct = (cur - base) / base * 100.0
            status = "FAIL" if base / cur > MAX_REGRESSION else "ok"
            rows.append((metric, f"{base:.2f}", f"{cur:.2f}",
                         f"{delta_pct:+.1f}%", status))
        else:
            rows.append((metric, "-", f"{cur:.2f}", "-", "n/a"))
    return rows


def print_table(rows):
    headers = ("metric", "baseline", "current", "delta", "status")
    widths = [max(len(str(r[i])) for r in rows + [headers])
              for i in range(len(headers))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print("bench gate: " + fmt.format(*headers))
    for row in rows:
        print("bench gate: " + fmt.format(*row))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--current", required=True, help="freshly-measured bench JSON")
    args = ap.parse_args()

    baseline = load(args.baseline)
    current = load(args.current)

    for metric in GATED_METRICS:
        value = current.get(metric)
        if not isinstance(value, (int, float)) or value <= 0:
            sys.exit(f"bench gate: current {metric} missing or non-positive: {value!r}")

    measured = bool(baseline.get("measured", False))
    rows = delta_rows(baseline, current, measured)
    print_table(rows)

    if not measured:
        print("bench gate: baseline is a bootstrap placeholder (measured=false);")
        print("bench gate: shape check passed, comparison skipped.")
        print("bench gate: promote a measured baseline with `make bench-baseline`.")
        return

    failures = [row[0] for row in rows if row[4] == "FAIL"]
    if failures:
        sys.exit(f"bench gate: >{MAX_REGRESSION:.0f}x throughput regression in: "
                 + ", ".join(failures))
    print("bench gate: within budget.")


if __name__ == "__main__":
    main()
