#!/usr/bin/env python3
"""CI invariants over SLO alert logs (DESIGN.md §10).

Scans the `*.alerts.jsonl` logs the e2e suite leaves behind when
`KF_E2E_ALERT_DIR` is set and fails if any log violates an alert
state-machine invariant:

  * a `resolved` transition with no prior `firing` for the same rule —
    i.e. the engine claimed to heal a breach it never reported;
  * duplicate transitions — per rule, `firing` and `resolved` must
    strictly alternate (the engine emits edges, not levels, so two
    consecutive `firing` lines for one rule means a lost edge);
  * an unknown `state` (anything other than firing/resolved — the log
    records transitions only, never ok/pending levels);
  * non-monotone timestamps within one log file.

Torn final lines (crash-cut logs) are tolerated the same way the Rust
loader tolerates them.

Usage: check_alerts.py <alert-dir>
"""

import glob
import json
import os
import sys


def scan(path):
    """Return the list of transition dicts in one alert log, in order."""
    out = []
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue  # torn tail from a crash-cut append
            raise SystemExit(f"{path}:{i + 1}: malformed mid-file alert line")
    return out


def check_log(path, transitions):
    """Return a list of invariant violations for one alert log."""
    problems = []
    last_state = {}  # rule -> last seen state
    last_ts = None
    for i, t in enumerate(transitions):
        rule, state, ts = t.get("rule"), t.get("state"), t.get("ts_ms")
        where = f"{path}:{i + 1}"
        if state not in ("firing", "resolved"):
            problems.append(f"{where}: rule {rule!r} has unknown state "
                            f"{state!r} (expected firing|resolved)")
            continue
        prev = last_state.get(rule)
        if state == "resolved" and prev is None:
            problems.append(f"{where}: rule {rule!r} resolved without a "
                            "prior firing")
        elif prev == state:
            problems.append(f"{where}: rule {rule!r} has duplicate "
                            f"'{state}' transitions (edges must alternate)")
        last_state[rule] = state
        if not isinstance(ts, (int, float)):
            problems.append(f"{where}: rule {rule!r} has non-numeric "
                            f"ts_ms {ts!r}")
        else:
            if last_ts is not None and ts < last_ts:
                problems.append(f"{where}: timestamps went backwards "
                                f"({ts} < {last_ts})")
            last_ts = ts
    return problems


def main():
    if len(sys.argv) != 2:
        raise SystemExit(__doc__)
    alert_dir = sys.argv[1]
    files = sorted(glob.glob(os.path.join(alert_dir, "*.alerts.jsonl")))
    if not files:
        raise SystemExit(f"no *.alerts.jsonl logs under {alert_dir}; "
                         "was KF_E2E_ALERT_DIR exported for the e2e run?")
    bad = []
    total = 0
    for path in files:
        transitions = scan(path)
        total += len(transitions)
        bad.extend(check_log(path, transitions))
    if bad:
        raise SystemExit("\n".join(bad))
    print(f"OK: {total} transition(s) across {len(files)} log(s); every "
          "resolved followed a firing, edges alternate, timestamps are "
          "monotone")


if __name__ == "__main__":
    main()
