#!/usr/bin/env python3
"""CI invariants over the chaos run's job journal (DESIGN.md §11).

Scans the `*.journal.jsonl` files the chaos e2e leaves behind when
`KF_E2E_FAULT_DIR` is set and independently re-folds the unit lineages
the same way daemon replay does, failing if fault handling violated a
durability invariant:

  * a unit with dispatch/retry activity never reached a terminal record
    (commit / fail / quarantine / cancel) and was not rerouted away —
    i.e. the fleet lost a unit;
  * a submitted (non-cached) unit's lineage, followed through reroutes,
    never terminates — i.e. the service lost a job;
  * a unit committed more than once — the exactly-once commit contract
    a retry must never break;
  * a unit both committed and carries a failure verdict — conflicting
    terminal states for one lineage.

The scan also requires at least one `retry` and one `quarantine` record
across the directory, proving the committed fault plan actually fired
(a chaos run where nothing went wrong tests nothing).

Torn final lines (crash-cut journals) are tolerated the same way the
Rust loader tolerates them.

Usage: check_faults.py <fault-dir>
"""

import glob
import json
import os
import sys


def scan(path):
    """Parse one journal into a list of record dicts."""
    records = []
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue  # torn tail from a crash-cut append
            raise SystemExit(f"{path}:{i + 1}: malformed mid-file journal line")
    return records


def fold(records):
    """Fold records into per-(job, device) lineages.

    Returns (lineages, submitted, counts) where lineages maps
    (job, device) -> {"active": bool, "terminals": [kinds],
    "commits": int, "rerouted_to": device | None} and submitted is the
    set of (job, device) units admitted by non-cached submit records.
    """
    lineages = {}
    submitted = set()
    counts = {"retry": 0, "quarantine": 0, "reroute": 0, "commit": 0}

    def lane(job, device):
        return lineages.setdefault(
            (job, device),
            {"active": False, "terminals": [], "commits": 0, "rerouted_to": None},
        )

    for rec in records:
        kind = rec.get("t")
        job = rec.get("job_id")
        if kind == "submit":
            for unit in rec.get("units", []):
                if not unit.get("cached"):
                    submitted.add((job, unit["device"]))
                    lane(job, unit["device"])
        elif kind == "dispatch":
            lane(job, rec["device"])["active"] = True
        elif kind == "retry":
            counts["retry"] += 1
            lane(job, rec["device"])["active"] = True
        elif kind == "reroute":
            counts["reroute"] += 1
            lane(job, rec["from"])["rerouted_to"] = rec["to"]
            lane(job, rec["to"])
        elif kind == "commit":
            counts["commit"] += 1
            entry = lane(job, rec["device"])
            entry["commits"] += 1
            entry["terminals"].append("commit")
        elif kind == "fail":
            lane(job, rec["device"])["terminals"].append("fail")
        elif kind == "quarantine":
            counts["quarantine"] += 1
            lane(job, rec["device"])["terminals"].append("quarantine")
        elif kind == "cancel":
            for device in rec.get("devices", []):
                lane(job, device)["terminals"].append("cancel")
    return lineages, submitted, counts


def terminates(lineages, job, device, seen=None):
    """Whether a lineage reaches a terminal record, following reroutes."""
    seen = seen or set()
    if (job, device) in seen:
        return False  # reroute cycle: nothing terminal on it
    seen.add((job, device))
    entry = lineages.get((job, device))
    if entry is None:
        return False
    if entry["terminals"]:
        return True
    if entry["rerouted_to"] is not None:
        return terminates(lineages, job, entry["rerouted_to"], seen)
    return False


def check(path, lineages, submitted):
    """Return a list of invariant violations for one journal."""
    problems = []
    for (job, device), entry in sorted(lineages.items()):
        where = f"{path}: job {job} unit {device}"
        if entry["commits"] > 1:
            problems.append(f"{where} committed {entry['commits']} times")
        if entry["commits"] and any(
            t in ("fail", "quarantine") for t in entry["terminals"]
        ):
            problems.append(
                f"{where} has conflicting terminal records: {entry['terminals']}"
            )
        if (
            entry["active"]
            and not entry["terminals"]
            and entry["rerouted_to"] is None
        ):
            problems.append(f"{where} was dispatched but never reached a verdict")
    for job, device in sorted(submitted):
        if not terminates(lineages, job, device):
            problems.append(
                f"{path}: job {job} unit {device} was submitted but its "
                "lineage never terminates (lost job)"
            )
    return problems


def main():
    if len(sys.argv) != 2:
        raise SystemExit(__doc__)
    fault_dir = sys.argv[1]
    files = sorted(glob.glob(os.path.join(fault_dir, "*.journal.jsonl")))
    if not files:
        raise SystemExit(f"no *.journal.jsonl files under {fault_dir}; "
                         "was KF_E2E_FAULT_DIR exported for the chaos run?")
    bad = []
    units = 0
    totals = {"retry": 0, "quarantine": 0, "reroute": 0, "commit": 0}
    for path in files:
        lineages, submitted, counts = fold(scan(path))
        units += len(lineages)
        for key in totals:
            totals[key] += counts[key]
        bad.extend(check(path, lineages, submitted))
    if totals["retry"] == 0:
        bad.append(f"{fault_dir}: no retry records — the fault plan never fired")
    if totals["quarantine"] == 0:
        bad.append(f"{fault_dir}: no quarantine records — the dead lane "
                   "never poisoned a unit")
    if bad:
        raise SystemExit("\n".join(bad))
    print(f"OK: {units} unit lineage(s) across {len(files)} journal(s); "
          f"{totals['retry']} retries, {totals['reroute']} reroutes, "
          f"{totals['quarantine']} quarantines, {totals['commit']} commits; "
          "every lineage terminated exactly once")


if __name__ == "__main__":
    main()
