#!/usr/bin/env python3
"""CI invariant over job-lifecycle trace sinks (DESIGN.md §8).

Scans the `*.trace.jsonl` sinks the e2e suite leaves behind when
`KF_E2E_TRACE_DIR` is set and fails if any job reached `executed`
without a matching `committed` event — i.e. a unit produced a verdict
that was never durably published. Torn final lines (crash-cut sinks)
are tolerated the same way the Rust loader tolerates them.

Usage: check_traces.py <trace-dir>
"""

import glob
import json
import os
import sys


def scan(path):
    """Return {job_id: set(stages)} for one trace sink."""
    stages = {}
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue  # torn tail from a crash-cut append
            raise SystemExit(f"{path}:{i + 1}: malformed mid-file trace line")
        stages.setdefault(ev["job"], set()).add(ev["t"])
    return stages


def main():
    if len(sys.argv) != 2:
        raise SystemExit(__doc__)
    trace_dir = sys.argv[1]
    files = sorted(glob.glob(os.path.join(trace_dir, "*.trace.jsonl")))
    if not files:
        raise SystemExit(f"no *.trace.jsonl sinks under {trace_dir}; "
                         "was KF_E2E_TRACE_DIR exported for the e2e run?")
    bad = []
    jobs = 0
    for path in files:
        for job, seen in sorted(scan(path).items()):
            jobs += 1
            if "executed" in seen and "committed" not in seen:
                bad.append(f"{path}: job {job} has 'executed' but no "
                           f"'committed' event (stages: {sorted(seen)})")
    if bad:
        raise SystemExit("\n".join(bad))
    print(f"OK: {jobs} job(s) across {len(files)} sink(s); "
          "every executed job was committed")


if __name__ == "__main__":
    main()
