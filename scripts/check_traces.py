#!/usr/bin/env python3
"""CI invariants over job-lifecycle trace sinks (DESIGN.md §8).

Scans the `*.trace.jsonl` sinks the e2e suite leaves behind when
`KF_E2E_TRACE_DIR` is set and fails if any job violates a lifecycle
ordering invariant:

  * a job reached `executed` without a matching `committed` event —
    i.e. a unit produced a verdict that was never durably published;
  * a job was `dispatched` without a preceding `queued` event — i.e. a
    lane picked up work the intake never admitted (the service writes
    `queued` strictly before pushing a unit onto the queue, so in a
    healthy sink the first `queued` always lands before the first
    `dispatched`).

Torn final lines (crash-cut sinks) are tolerated the same way the Rust
loader tolerates them.

Usage: check_traces.py <trace-dir>
"""

import glob
import json
import os
import sys


def scan(path):
    """Return {job_id: [stages in file order]} for one trace sink."""
    stages = {}
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue  # torn tail from a crash-cut append
            raise SystemExit(f"{path}:{i + 1}: malformed mid-file trace line")
        # Alert transitions and lane circuit-breaker flips are mirrored
        # into the sink as fleet-health events (job 0, stage `alert_*` /
        # `lane_*`) — they are not job lifecycle stages, so they never
        # participate in the ordering invariants.
        if str(ev["t"]).startswith(("alert", "lane")):
            continue
        stages.setdefault(ev["job"], []).append(ev["t"])
    return stages


def check_job(path, job, ordered):
    """Return a list of invariant violations for one job's stage list."""
    problems = []
    seen = set(ordered)
    if "executed" in seen and "committed" not in seen:
        problems.append(f"{path}: job {job} has 'executed' but no "
                        f"'committed' event (stages: {sorted(seen)})")
    if "dispatched" in seen:
        if "queued" not in seen:
            problems.append(f"{path}: job {job} was 'dispatched' but never "
                            f"'queued' (stages: {sorted(seen)})")
        elif ordered.index("queued") > ordered.index("dispatched"):
            problems.append(f"{path}: job {job} has 'dispatched' before "
                            f"'queued' in write order (stages: {ordered})")
    return problems


def main():
    if len(sys.argv) != 2:
        raise SystemExit(__doc__)
    trace_dir = sys.argv[1]
    files = sorted(glob.glob(os.path.join(trace_dir, "*.trace.jsonl")))
    if not files:
        raise SystemExit(f"no *.trace.jsonl sinks under {trace_dir}; "
                         "was KF_E2E_TRACE_DIR exported for the e2e run?")
    bad = []
    jobs = 0
    for path in files:
        for job, ordered in sorted(scan(path).items()):
            jobs += 1
            bad.extend(check_job(path, job, ordered))
    if bad:
        raise SystemExit("\n".join(bad))
    print(f"OK: {jobs} job(s) across {len(files)} sink(s); every executed "
          "job was committed and every dispatch followed its queue entry")


if __name__ == "__main__":
    main()
